//! Memory-constrained schedule auto-tuner.
//!
//! The paper's Figs 4/5/7 show that 2BP's throughput win is bounded by
//! peak memory: full p2 deferral is fastest but OOMs first, and the
//! best *valid* schedule depends on the budget, the f:p1:p2 cost shape,
//! and the microbatch count.  This module turns the fixed schedule zoo
//! into a search (PipeDream/BaPipe-style): given a [`TuneProfile`]
//! (per-stage costs + per-microbatch byte classes) and a per-rank byte
//! budget, [`beam::tune`] finds the best-throughput plan that fits.
//!
//! Three layers:
//!
//! * **seeding** — every generator schedule (± 2BP) across a microbatch
//!   grid, plus partial-flush-enriched 2BP variants (the Fig 5 knob,
//!   generalized to arbitrary flush points);
//! * **local moves** ([`moves`]) — swap/shift/flush-point mutations,
//!   each gated by *incremental revalidation* (every move declares
//!   which validator invariants it can break and rechecks only those,
//!   with a full-`validate` differential debug-assert) so the search
//!   space stays inside legal plans without paying a full validation
//!   pass per candidate;
//! * **beam search** ([`beam`]) — deterministic seeded beam over the
//!   candidates, deduped by [`crate::schedule::Plan::fingerprint`] and
//!   evaluated through the Tier A scoring fast path
//!   ([`crate::sim::score_plan`] + one reusable
//!   [`crate::sim::Scratch`] per worker — span-free and
//!   allocation-free; see the two-tier contract in [`crate::sim`]),
//!   with hard rejection of budget-violating plans via `max_peak`.
//!   Winners are re-rendered through Tier B ([`crate::sim::eval_plan`])
//!   when a timeline is needed.
//!
//! Winners serialize through the plan DSL
//! ([`crate::schedule::plan_io`]), so a found schedule is a `.plan`
//! file any other subcommand (gantt, simulate, sweep) can replay.
//!
//! # The measured-profile path (calibration loop)
//!
//! Profiles don't have to be hand-tuned ratios: `twobp tune
//! --synthetic` (or `--manifest <preset-dir>`, both under the `pjrt`
//! feature) closes the executor→planner→executor circle.  It runs a
//! few contention-free calibration steps on the real executor
//! (`pipeline::Cluster::calibrate`), builds a
//! [`TuneProfile::from_measured`] out of the measured per-stage costs
//! (`pipeline::RunReport::measured_costs`) and the manifest's
//! byte classes (`Manifest::mem_model`), beam-searches against that
//! measured profile, then **executes the winning plan back on the
//! executor** (`pipeline::Cluster::run_plan`) — verifying it
//! against the simulator and reporting predicted-vs-executed makespan
//! (see `experiments::tune_calibrated`).  BaPipe and PipeDream both
//! found profiling-driven schedule search beats static heuristics;
//! this is that loop, testable offline against the stub backend.

pub mod beam;
pub mod cosearch;
pub mod moves;
pub mod partition;

pub use beam::{
    tune, BeamConfig, Candidate, RobustObjective, TuneOutcome, TuneReport,
    TuneRequest,
};
pub use cosearch::{co_search, CoSearchConfig, CoSearchReport};
pub use partition::{LayerProfile, ModelProfile};

use crate::sim::{CostModel, MemModel};

/// What the planner tunes against: a model's per-rank op costs and
/// per-microbatch byte classes.  The budget itself is part of
/// [`BeamConfig`], not the profile, so one profile can be tuned at
/// several budgets.
#[derive(Debug, Clone)]
pub struct TuneProfile {
    /// Profile name for reports (e.g. "llama-like").
    pub name: String,
    pub costs: CostModel,
    pub mem: MemModel,
    /// Samples per microbatch (throughput = samples/sec).
    pub samples_per_microbatch: usize,
    /// Costs come from wall-clock measurement
    /// ([`TuneProfile::from_measured`]) rather than abstract ratios.
    /// Telemetry uses this to decide whether score-derived metrics are
    /// deterministic or must be quarantined under `"wall"` in the run
    /// log (see `metrics::registry`).
    pub measured: bool,
}

impl TuneProfile {
    /// A LLaMa-7b-like transformer profile at pipeline depth `n_ranks`
    /// (the paper's Table 2 LLaMa row, reduced to per-rank aggregates).
    ///
    /// Cost shape: backward ≈ 2× forward, split into an input-grad half
    /// (p1, marginally dearer: attention re-reads) and a weight-grad
    /// half (p2); a small optimizer step and a last-rank loss; adjacent
    /// hops cost ~5% of a forward.  Byte classes follow the §4.2
    /// taxonomy with transformer-typical ratios: the p1-consumed stash
    /// (res1) dominates, the p2 stash (res2) is weights-sized, and the
    /// intermediate derivative (inter) sits between.
    pub fn llama_like(n_ranks: usize) -> TuneProfile {
        const GIB: u64 = 1 << 30;
        let mut costs = CostModel::ratios(n_ranks, 1.0, 1.05, 0.95);
        costs.opt = vec![0.15; n_ranks];
        costs.loss = 0.2;
        costs.comm = 0.05;
        // Table 3 measured concat ≈ break-even; give it a slight win
        // (saved dispatch overhead) so the planner's toggle-concat move
        // explores a live trade-off instead of timing-identical twins
        costs.concat_factor = 0.97;
        let mem = MemModel {
            // params + grads + Adam m/v, per rank
            static_bytes: vec![5 * GIB / 2; n_ranks],
            res1: vec![300 * GIB / 1024; n_ranks], // 300 MiB / microbatch
            res2: vec![120 * GIB / 1024; n_ranks], // 120 MiB / microbatch
            inter: vec![180 * GIB / 1024; n_ranks], // 180 MiB / microbatch
        };
        TuneProfile {
            name: "llama-like".into(),
            costs,
            mem,
            samples_per_microbatch: 1,
            measured: false,
        }
    }

    /// A profile from **measured** per-stage costs and manifest byte
    /// classes — what the calibration loop tunes against, replacing the
    /// ratio-only profiles for any preset the executor can run.  Costs
    /// come from `pipeline::RunReport::measured_costs` (real
    /// seconds, loss attributed separately), memory from
    /// `Manifest::mem_model` (byte-exact per-microbatch classes), so
    /// the search optimizes real samples/sec under the real OOM line.
    /// Errors if the cost and memory shapes disagree on rank count —
    /// a mismatched pair would tune one model's schedule under another
    /// model's memory.
    pub fn from_measured(
        name: impl Into<String>,
        costs: CostModel,
        mem: MemModel,
        samples_per_microbatch: usize,
    ) -> Result<TuneProfile, String> {
        if costs.fwd.len() != mem.static_bytes.len() {
            return Err(format!(
                "measured profile shape mismatch: costs cover {} ranks, \
                 memory covers {}",
                costs.fwd.len(),
                mem.static_bytes.len()
            ));
        }
        Ok(TuneProfile {
            name: name.into(),
            costs,
            mem,
            samples_per_microbatch,
            measured: true,
        })
    }

    /// A profile from explicit cost ratios with the LLaMa-like byte
    /// classes (the `twobp tune` CLI path when the user overrides the
    /// cost shape but not the memory shape).  Only fwd/p1/p2/comm are
    /// replaced — opt, loss, and the memory classes keep their
    /// [`TuneProfile::llama_like`] values, so passing a flag at its
    /// default value does not silently change the tuning landscape.
    pub fn from_ratios(
        n_ranks: usize,
        fwd: f64,
        p1: f64,
        p2: f64,
        comm: f64,
    ) -> TuneProfile {
        let mut p = TuneProfile::llama_like(n_ranks);
        p.name = format!("ratios {fwd}:{p1}:{p2} comm={comm}");
        p.costs.fwd = vec![fwd; n_ranks];
        p.costs.p1 = vec![p1; n_ranks];
        p.costs.p2 = vec![p2; n_ranks];
        p.costs.comm = comm;
        p
    }

    /// Stable structural fingerprint of everything a search result can
    /// depend on through the profile: name, every cost-model entry,
    /// every byte class, samples per microbatch, and the measured flag.
    /// Same FNV-1a construction as [`crate::schedule::Plan::fingerprint`]
    /// (floats hashed by their IEEE bits).  Combined with
    /// [`beam::TuneRequest::fingerprint`] this keys the serve daemon's
    /// result cache.
    pub fn fingerprint(&self) -> u64 {
        const PRIME: u64 = 0x0000_0100_0000_01b3;
        let mut h: u64 = 0xcbf2_9ce4_8422_2325;
        let mut mix = |x: u64| {
            for b in x.to_le_bytes() {
                h = (h ^ b as u64).wrapping_mul(PRIME);
            }
        };
        // length-prefixed name bytes keep the encoding injective
        mix(self.name.len() as u64);
        for b in self.name.bytes() {
            mix(b as u64);
        }
        let c = &self.costs;
        for series in [&c.fwd, &c.p1, &c.p2, &c.opt] {
            mix(series.len() as u64);
            for v in series.iter() {
                mix(v.to_bits());
            }
        }
        mix(c.loss.to_bits());
        mix(c.comm.to_bits());
        mix(c.comm_inter_node.to_bits());
        mix(c.ranks_per_node as u64);
        mix(c.concat_factor.to_bits());
        let m = &self.mem;
        for series in [&m.static_bytes, &m.res1, &m.res2, &m.inter] {
            mix(series.len() as u64);
            for v in series.iter() {
                mix(*v);
            }
        }
        mix(self.samples_per_microbatch as u64);
        mix(self.measured as u64);
        h
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn llama_like_shapes_match_rank_count() {
        let p = TuneProfile::llama_like(4);
        assert_eq!(p.costs.fwd.len(), 4);
        assert_eq!(p.mem.res1.len(), 4);
        assert!(p.mem.res1[0] > p.mem.inter[0]);
        assert!(p.mem.inter[0] > p.mem.res2[0]);
    }

    #[test]
    fn from_measured_builds_and_rejects_shape_mismatch() {
        let mut costs = CostModel::ratios(3, 0.002, 0.0021, 0.0019);
        costs.loss = 0.0003;
        let mem = MemModel {
            static_bytes: vec![10; 3],
            res1: vec![4; 3],
            res2: vec![2; 3],
            inter: vec![3; 3],
        };
        let p = TuneProfile::from_measured(
            "measured synthetic", costs.clone(), mem, 2,
        )
        .unwrap();
        assert_eq!(p.name, "measured synthetic");
        assert_eq!(p.samples_per_microbatch, 2);
        assert!(p.measured, "measured profiles must self-identify");
        assert_eq!(p.costs.fwd, vec![0.002; 3]);
        assert_eq!(p.costs.loss, 0.0003);
        let bad_mem = MemModel {
            static_bytes: vec![10; 2],
            res1: vec![4; 2],
            res2: vec![2; 2],
            inter: vec![3; 2],
        };
        let err =
            TuneProfile::from_measured("x", costs, bad_mem, 1).unwrap_err();
        assert!(err.contains("shape mismatch"), "{err}");
    }

    #[test]
    fn profile_fingerprint_tracks_every_field() {
        let base = TuneProfile::llama_like(4);
        let fp = base.fingerprint();
        assert_eq!(fp, base.clone().fingerprint());
        let mut name = base.clone();
        name.name.push('!');
        assert_ne!(name.fingerprint(), fp);
        let mut cost = base.clone();
        cost.costs.p2[1] += 0.001;
        assert_ne!(cost.fingerprint(), fp);
        let mut mem = base.clone();
        mem.mem.res1[0] += 1;
        assert_ne!(mem.fingerprint(), fp);
        let mut measured = base.clone();
        measured.measured = true;
        assert_ne!(measured.fingerprint(), fp);
        let mut samples = base.clone();
        samples.samples_per_microbatch += 1;
        assert_ne!(samples.fingerprint(), fp);
        // distinct rank counts are distinct profiles
        assert_ne!(TuneProfile::llama_like(2).fingerprint(), fp);
    }

    #[test]
    fn from_ratios_overrides_costs_only() {
        let p = TuneProfile::from_ratios(2, 1.0, 0.5, 1.5, 0.1);
        assert!(!p.measured, "ratio profiles are deterministic");
        assert_eq!(p.costs.p2[0], 1.5);
        assert_eq!(p.costs.comm, 0.1);
        assert_eq!(p.mem.static_bytes.len(), 2);
        // opt/loss (and memory classes) keep the llama-like values, so
        // flags at their default values don't shift the landscape
        let base = TuneProfile::llama_like(2);
        assert_eq!(p.costs.opt, base.costs.opt);
        assert_eq!(p.costs.loss, base.costs.loss);
        assert_eq!(p.costs.concat_factor, base.costs.concat_factor);
    }
}
