//! Per-layer cost/memory model and its roll-up through a
//! [`Partition`] — the refactor that turns "stage s costs X" into
//! "stage s owns layers a..b and costs what they sum to".
//!
//! A [`ModelProfile`] describes the *model*: one [`LayerProfile`] per
//! layer (fwd/p1/p2/opt seconds plus the §4.2 byte classes), with the
//! whole-pipeline scalars (loss, hop latency, concat factor) carried
//! alongside.  [`ModelProfile::roll_up`] folds it through a
//! [`Partition`] into exactly the per-stage [`TuneProfile`] every
//! existing consumer (`sim::score_plan`, `MemModel`, the beam) already
//! expects — so the sim kernel never learns about layers, and the
//! trivial one-layer-per-stage partition is **bit-identical** to the
//! old per-stage path (enforced by a differential proptest below).
//!
//! Stage aggregation rules:
//!
//! * costs (`fwd`/`p1`/`p2`/`opt`) and residency bytes
//!   (`param_bytes` → `static_bytes`, `res1`, `res2`) **sum** over the
//!   stage's layers — all of them run / live on that stage;
//! * `inter` (the p1→p2 intermediate derivative) takes the **last**
//!   layer's value: within a stage the per-layer intermediates are
//!   consumed back-to-back and only the stage-boundary one is stashed
//!   across the p1/p2 split.
//!
//! The DP side: `allreduce_per_byte` prices the per-step ring
//! allreduce a replicated pipeline pays (see
//! [`crate::sim::allreduce_time`]); the co-search adds that term
//! *outside* the sim kernel, keeping Tier A untouched.

use crate::schedule::Partition;
use crate::sim::{CostModel, MemModel};

use super::TuneProfile;

/// One model layer's op costs (seconds) and byte classes.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct LayerProfile {
    pub fwd: f64,
    pub p1: f64,
    pub p2: f64,
    pub opt: f64,
    /// Params + grads + optimizer state for this layer (rolls up into
    /// `MemModel::static_bytes`, and prices the DP allreduce).
    pub param_bytes: u64,
    /// Per-microbatch stash released at p1.
    pub res1: u64,
    /// Per-microbatch stash held to p2.
    pub res2: u64,
    /// Per-microbatch p1→p2 intermediate derivative.
    pub inter: u64,
}

/// A model described layer-by-layer, plus the whole-pipeline scalars.
/// Fold it through a [`Partition`] with [`ModelProfile::roll_up`] to
/// get the per-stage [`TuneProfile`] the planner tunes against.
#[derive(Debug, Clone)]
pub struct ModelProfile {
    pub name: String,
    pub layers: Vec<LayerProfile>,
    /// Loss + initial-gradient cost on the last stage.
    pub loss: f64,
    /// Activation/gradient hop latency between adjacent stages.
    pub comm: f64,
    pub comm_inter_node: f64,
    pub ranks_per_node: usize,
    pub concat_factor: f64,
    /// Ring-allreduce seconds per gradient byte (the DP > 1 cost; 0
    /// disables the term — pure-PP searches are unaffected).
    pub allreduce_per_byte: f64,
    pub samples_per_microbatch: usize,
    pub measured: bool,
}

impl ModelProfile {
    /// Reinterpret a per-stage [`TuneProfile`] as a per-layer model:
    /// stage s of the old world becomes layer s ("stage s *is* layer
    /// s").  `roll_up(Partition::trivial(n))` is then the exact
    /// inverse — the differential anchor for the whole refactor.
    pub fn from_profile(p: &TuneProfile) -> ModelProfile {
        let n = p.costs.fwd.len();
        let layers = (0..n)
            .map(|i| LayerProfile {
                fwd: p.costs.fwd[i],
                p1: p.costs.p1[i],
                p2: p.costs.p2[i],
                opt: p.costs.opt[i],
                param_bytes: p.mem.static_bytes[i],
                res1: p.mem.res1[i],
                res2: p.mem.res2[i],
                inter: p.mem.inter[i],
            })
            .collect();
        ModelProfile {
            name: p.name.clone(),
            layers,
            loss: p.costs.loss,
            comm: p.costs.comm,
            comm_inter_node: p.costs.comm_inter_node,
            ranks_per_node: p.costs.ranks_per_node,
            concat_factor: p.costs.concat_factor,
            allreduce_per_byte: 0.0,
            samples_per_microbatch: p.samples_per_microbatch,
            measured: p.measured,
        }
    }

    pub fn n_layers(&self) -> usize {
        self.layers.len()
    }

    /// Fold the per-layer model through `part` into the per-stage
    /// [`TuneProfile`] every existing consumer expects (aggregation
    /// rules in the module docs).  Errors when the partition is
    /// malformed or covers a different layer count.
    pub fn roll_up(&self, part: &Partition) -> Result<TuneProfile, String> {
        part.check()?;
        if part.n_layers() != self.layers.len() {
            return Err(format!(
                "partition covers {} layers but the model has {}",
                part.n_layers(),
                self.layers.len()
            ));
        }
        let n = part.n_stages();
        let mut costs = CostModel {
            fwd: Vec::with_capacity(n),
            p1: Vec::with_capacity(n),
            p2: Vec::with_capacity(n),
            opt: Vec::with_capacity(n),
            loss: self.loss,
            comm: self.comm,
            comm_inter_node: self.comm_inter_node,
            ranks_per_node: self.ranks_per_node,
            concat_factor: self.concat_factor,
        };
        let mut mem = MemModel {
            static_bytes: Vec::with_capacity(n),
            res1: Vec::with_capacity(n),
            res2: Vec::with_capacity(n),
            inter: Vec::with_capacity(n),
        };
        for s in 0..n {
            let ls = &self.layers[part.layers(s)];
            // fold from the first layer (not 0.0) so a single-layer
            // stage reproduces the layer's bits exactly — the trivial
            // partition must round-trip bit-for-bit
            costs.fwd.push(sum_from_first(ls, |l| l.fwd));
            costs.p1.push(sum_from_first(ls, |l| l.p1));
            costs.p2.push(sum_from_first(ls, |l| l.p2));
            costs.opt.push(sum_from_first(ls, |l| l.opt));
            mem.static_bytes
                .push(ls.iter().map(|l| l.param_bytes).sum());
            mem.res1.push(ls.iter().map(|l| l.res1).sum());
            mem.res2.push(ls.iter().map(|l| l.res2).sum());
            mem.inter.push(ls[ls.len() - 1].inter);
        }
        Ok(TuneProfile {
            name: self.name.clone(),
            costs,
            mem,
            samples_per_microbatch: self.samples_per_microbatch,
            measured: self.measured,
        })
    }

    /// Total parameter bytes of the heaviest stage under `part` — the
    /// ring-allreduce bottleneck when the pipeline is replicated
    /// (stages allreduce concurrently; the fattest one finishes last).
    pub fn max_stage_param_bytes(&self, part: &Partition) -> u64 {
        (0..part.n_stages())
            .map(|s| {
                self.layers[part.layers(s)]
                    .iter()
                    .map(|l| l.param_bytes)
                    .sum()
            })
            .max()
            .unwrap_or(0)
    }

    /// Stable structural fingerprint (same FNV-1a construction as
    /// [`crate::schedule::Plan::fingerprint`], floats by IEEE bits).
    /// The serve daemon keys co-search cache entries on this, so a
    /// re-calibrated layer profile can never alias a stale result.
    pub fn fingerprint(&self) -> u64 {
        const PRIME: u64 = 0x0000_0100_0000_01b3;
        let mut h: u64 = 0xcbf2_9ce4_8422_2325;
        let mut mix = |x: u64| {
            for b in x.to_le_bytes() {
                h = (h ^ b as u64).wrapping_mul(PRIME);
            }
        };
        mix(self.name.len() as u64);
        for b in self.name.bytes() {
            mix(b as u64);
        }
        mix(self.layers.len() as u64);
        for l in &self.layers {
            mix(l.fwd.to_bits());
            mix(l.p1.to_bits());
            mix(l.p2.to_bits());
            mix(l.opt.to_bits());
            mix(l.param_bytes);
            mix(l.res1);
            mix(l.res2);
            mix(l.inter);
        }
        mix(self.loss.to_bits());
        mix(self.comm.to_bits());
        mix(self.comm_inter_node.to_bits());
        mix(self.ranks_per_node as u64);
        mix(self.concat_factor.to_bits());
        mix(self.allreduce_per_byte.to_bits());
        mix(self.samples_per_microbatch as u64);
        mix(self.measured as u64);
        h
    }
}

/// Sum a projected field starting from the slice's first element, so a
/// one-element slice returns that element's bits unchanged (`0.0 + x`
/// would lose a negative zero; starting at `x` never rewrites bits).
fn sum_from_first(ls: &[LayerProfile], f: impl Fn(&LayerProfile) -> f64) -> f64 {
    ls[1..].iter().fold(f(&ls[0]), |acc, l| acc + f(l))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::schedule::{generate, ScheduleKind};
    use crate::sim::{eval_plan, score_plan, Scratch};
    use crate::util::proptest::{check, gen};

    #[test]
    fn from_profile_then_trivial_roll_up_is_identity() {
        let p = TuneProfile::llama_like(4);
        let mp = ModelProfile::from_profile(&p);
        assert_eq!(mp.n_layers(), 4);
        let back = mp.roll_up(&Partition::trivial(4)).unwrap();
        assert_eq!(back.name, p.name);
        assert_eq!(back.costs.fwd, p.costs.fwd);
        assert_eq!(back.costs.p1, p.costs.p1);
        assert_eq!(back.costs.p2, p.costs.p2);
        assert_eq!(back.costs.opt, p.costs.opt);
        assert_eq!(back.costs.loss, p.costs.loss);
        assert_eq!(back.mem.static_bytes, p.mem.static_bytes);
        assert_eq!(back.mem.res1, p.mem.res1);
        assert_eq!(back.mem.res2, p.mem.res2);
        assert_eq!(back.mem.inter, p.mem.inter);
        // the fingerprints every cache keys on agree too
        assert_eq!(back.fingerprint(), p.fingerprint());
    }

    #[test]
    fn roll_up_sums_costs_and_takes_the_boundary_inter() {
        let mut mp = ModelProfile::from_profile(&TuneProfile::llama_like(4));
        for (i, l) in mp.layers.iter_mut().enumerate() {
            l.fwd = (i + 1) as f64;
            l.param_bytes = 100 * (i as u64 + 1);
            l.inter = 10 + i as u64;
        }
        let part = Partition { cuts: vec![0, 3, 4], dp: 1 };
        let rolled = mp.roll_up(&part).unwrap();
        assert_eq!(rolled.costs.fwd, vec![1.0 + 2.0 + 3.0, 4.0]);
        assert_eq!(rolled.mem.static_bytes, vec![600, 400]);
        // inter is the stage's *last* layer's (the boundary derivative)
        assert_eq!(rolled.mem.inter, vec![12, 13]);
        assert_eq!(mp.max_stage_param_bytes(&part), 600);
    }

    #[test]
    fn roll_up_rejects_mismatched_and_malformed_partitions() {
        let mp = ModelProfile::from_profile(&TuneProfile::llama_like(4));
        let err = mp.roll_up(&Partition::trivial(5)).unwrap_err();
        assert!(err.contains("5 layers"), "{err}");
        let bad = Partition { cuts: vec![0, 4, 4], dp: 1 };
        assert!(mp.roll_up(&bad).is_err());
    }

    #[test]
    fn model_fingerprint_tracks_layer_and_dp_fields() {
        let base = ModelProfile::from_profile(&TuneProfile::llama_like(3));
        let fp = base.fingerprint();
        let mut l = base.clone();
        l.layers[1].p2 += 0.25;
        assert_ne!(l.fingerprint(), fp);
        let mut b = base.clone();
        b.layers[0].param_bytes += 1;
        assert_ne!(b.fingerprint(), fp);
        let mut a = base.clone();
        a.allreduce_per_byte = 1e-9;
        assert_ne!(a.fingerprint(), fp);
    }

    /// Tentpole acceptance: rolling a fuzzed per-layer model up through
    /// the **trivial** partition reproduces the old per-stage path
    /// bit-for-bit through both evaluation tiers (`score_plan` and
    /// `eval_plan`) — makespan, busy, bubble, peak, fit.
    #[test]
    fn prop_trivial_partition_is_bit_identical_to_per_stage() {
        let mut scratch_a = Scratch::new();
        let mut scratch_b = Scratch::new();
        check(
            "trivial-partition roll-up == per-stage profile, bit-for-bit",
            120,
            |rng| {
                let n = gen::usize_in(rng, 1, 8);
                let m = gen::usize_in(rng, 1, 12);
                let kind = *gen::pick(rng, &ScheduleKind::all_variants());
                let two_bp = if kind == ScheduleKind::OneF1B2EagerP2 {
                    true
                } else {
                    gen::bool(rng)
                };
                // skewed costs/bytes so stage identity matters
                let f = gen::usize_in(rng, 1, 40) as f64 / 10.0;
                let p1 = gen::usize_in(rng, 1, 40) as f64 / 10.0;
                let p2 = gen::usize_in(rng, 1, 40) as f64 / 10.0;
                let comm = gen::usize_in(rng, 0, 10) as f64 / 20.0;
                let skew = gen::usize_in(rng, 1, 5) as u64;
                (n, m, kind, two_bp, f, p1, p2, comm, skew)
            },
            |&(n, m, kind, two_bp, f, p1, p2, comm, skew)| {
                let mut prof = TuneProfile::from_ratios(n, f, p1, p2, comm);
                for r in 0..n {
                    // per-stage skew: uniform profiles would hide
                    // roll-up indexing bugs
                    prof.costs.fwd[r] *= 1.0 + r as f64 / 7.0;
                    prof.mem.res1[r] = prof.mem.res1[r] / 2 + skew * r as u64;
                    prof.mem.inter[r] += skew * (n - r) as u64;
                }
                let rolled = ModelProfile::from_profile(&prof)
                    .roll_up(&Partition::trivial(n))?;
                let plan = generate(kind, two_bp, n, m, false);
                let budget = Some(prof.mem.static_bytes[0] * 2);
                let a = score_plan(
                    &plan, &prof.costs, Some(&prof.mem), budget,
                    &mut scratch_a,
                )
                .map_err(|e| format!("old path: {e}"))?;
                let b = score_plan(
                    &plan, &rolled.costs, Some(&rolled.mem), budget,
                    &mut scratch_b,
                )
                .map_err(|e| format!("rolled path: {e}"))?;
                if a.makespan.to_bits() != b.makespan.to_bits()
                    || a.total_busy.to_bits() != b.total_busy.to_bits()
                    || a.bubble_ratio.to_bits() != b.bubble_ratio.to_bits()
                    || a.max_peak != b.max_peak
                    || a.fits != b.fits
                {
                    return Err(format!("scores drifted: {a:?} vs {b:?}"));
                }
                // Tier B agrees too (validate + spans + budget check)
                let ea = eval_plan(&plan, &prof.costs, Some(&prof.mem), budget)
                    .map_err(|e| format!("old eval: {e}"))?;
                let eb = eval_plan(
                    &plan, &rolled.costs, Some(&rolled.mem), budget,
                )
                .map_err(|e| format!("rolled eval: {e}"))?;
                if ea.result.makespan.to_bits()
                    != eb.result.makespan.to_bits()
                    || ea.max_peak != eb.max_peak
                    || ea.fits != eb.fits
                {
                    return Err("eval_plan drifted".into());
                }
                Ok(())
            },
        );
    }
}
