//! Offline, deterministic stub of the `xla` PJRT crate.
//!
//! This workspace builds with no registry access, so the real
//! `xla`/PJRT bindings cannot be fetched or linked.  This crate
//! implements exactly the API surface `twobp::runtime` consumes —
//! [`PjRtClient`], [`Literal`], [`PjRtBuffer`], [`HloModuleProto`],
//! [`XlaComputation`], [`PjRtLoadedExecutable`], [`ElementType`],
//! [`PrimitiveType`], [`Shape`] — with shape-correct, reproducible
//! semantics instead of real compute, so the whole Layer-3 executor
//! (stage workers, comm, stash accounting, measurement) runs end to
//! end with no network, no Python artifacts, and no native deps.
//!
//! # The stub-HLO text format
//!
//! Instead of real HLO text, executables are described by a tiny
//! signature file (written by `twobp::models::synthetic`):
//!
//! ```text
//! stub-hlo v1
//! module synthetic/s0_fwd
//! seed 12345
//! out f32[2,4,8]
//! out s32[2,4]
//! ```
//!
//! Optional directives select the execution mode:
//!
//! * *(none)* — **plain**: each declared output is filled with values
//!   from a PRNG seeded by `(file seed, hash of all inputs, output
//!   index)`.  Outputs are a pure function of the inputs, so reruns and
//!   cross-schedule comparisons are reproducible.
//! * `acc N` — **accumulate** (the backward-p2 executable): the last N
//!   inputs are elementwise accumulators; output j is accumulator j
//!   plus a *small-integer-valued* f32 delta derived from the non-
//!   accumulator inputs only.  Integer deltas make f32 accumulation
//!   exact, hence **order-independent** — exactly the property real
//!   gradient accumulation has, and what lets the executor's greedy /
//!   reordered / concatenated p2 schedules produce bit-identical
//!   parameters.
//! * `group K` — **grouped sum** (the concatenated-p2 executable):
//!   inputs arrive as consecutive groups of K; each output sums one
//!   delta per group, seeded identically to `acc` mode, so a single
//!   concatenated call equals the per-microbatch loop bit for bit.
//! * `cost N` — **busy delay**: every execution of this signature
//!   sleeps N nanoseconds before computing its outputs.  Values stay
//!   bit-identical; only wall time changes.  This is how synthetic
//!   manifests give each stage a *measurable* op cost proportional to
//!   its declared flops, so measured-cost calibration
//!   (`twobp tune --synthetic`) has real per-stage skew to find.
//! * `drift C:N` — **cost drift**: the first C executions of a
//!   compiled executable sleep `cost` nanoseconds as usual; from call
//!   C onward the delay switches to N nanoseconds.  Values never
//!   change — only timing does — so a synthetic run can *provably*
//!   diverge from its calibrated cost model mid-run (the drift-replan
//!   smoke: `twobp tune --synthetic --replan`).  The call counter
//!   lives on the executable, so each worker's compiled stage drifts
//!   independently of its siblings.
//! * `fault <kind>@<call>` — **deterministic fault injection**: from
//!   execution number `<call>` (0-based, per compiled executable like
//!   `drift`) onward the executable misbehaves.  Kind `fail` returns a
//!   stub error on every execution from that point — a deterministic
//!   stand-in for a crashed device — and kind `stall-<ns>` sleeps N
//!   nanoseconds before computing (values stay bit-identical), the
//!   stand-in for a wedged-but-alive peer that comm deadlines must
//!   catch.  This is what `twobp train --synthetic --fault` and the
//!   `twobp bench faults` recovery harness inject.
//!
//! Everything is deliberately `Rc`-based and single-threaded, matching
//! the real crate's client threading model (one client per worker
//! thread).

use std::borrow::Borrow;
use std::cell::Cell;
use std::path::Path;
use std::rc::Rc;

// ---------------------------------------------------------------------------
// Errors
// ---------------------------------------------------------------------------

/// Stub error: a single message (the runtime formats errors with
/// `{:?}` and wraps them in its own context chain).
#[derive(Debug, Clone)]
pub struct Error(pub String);

impl std::fmt::Display for Error {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "xla-stub: {}", self.0)
    }
}

impl std::error::Error for Error {}

pub type Result<T> = std::result::Result<T, Error>;

fn err(msg: impl Into<String>) -> Error {
    Error(msg.into())
}

// ---------------------------------------------------------------------------
// Element types and shapes
// ---------------------------------------------------------------------------

/// XLA element types (the stub computes with F32/S32 only; the rest
/// exist so downstream `match` arms over foreign types stay reachable).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum ElementType {
    Pred,
    S8,
    S16,
    S32,
    S64,
    U8,
    U16,
    U32,
    U64,
    F16,
    Bf16,
    F32,
    F64,
    C64,
    C128,
}

impl ElementType {
    fn size_bytes(self) -> Option<usize> {
        match self {
            ElementType::Pred | ElementType::S8 | ElementType::U8 => Some(1),
            ElementType::S16
            | ElementType::U16
            | ElementType::F16
            | ElementType::Bf16 => Some(2),
            ElementType::S32 | ElementType::U32 | ElementType::F32 => Some(4),
            ElementType::S64
            | ElementType::U64
            | ElementType::F64
            | ElementType::C64 => Some(8),
            ElementType::C128 => Some(16),
        }
    }

    fn tag(self) -> u8 {
        match self {
            ElementType::Pred => 0,
            ElementType::S8 => 1,
            ElementType::S16 => 2,
            ElementType::S32 => 3,
            ElementType::S64 => 4,
            ElementType::U8 => 5,
            ElementType::U16 => 6,
            ElementType::U32 => 7,
            ElementType::U64 => 8,
            ElementType::F16 => 9,
            ElementType::Bf16 => 10,
            ElementType::F32 => 11,
            ElementType::F64 => 12,
            ElementType::C64 => 13,
            ElementType::C128 => 14,
        }
    }
}

/// Primitive types accepted by [`Literal::create_from_shape`].
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum PrimitiveType {
    Pred,
    S32,
    S64,
    F32,
    F64,
}

impl PrimitiveType {
    fn element_type(self) -> ElementType {
        match self {
            PrimitiveType::Pred => ElementType::Pred,
            PrimitiveType::S32 => ElementType::S32,
            PrimitiveType::S64 => ElementType::S64,
            PrimitiveType::F32 => ElementType::F32,
            PrimitiveType::F64 => ElementType::F64,
        }
    }
}

/// Host types a [`Literal`] can be read as / built from.
pub trait NativeType: Copy {
    const TY: ElementType;
    fn to_le(self) -> [u8; 4];
    fn from_le(bytes: &[u8]) -> Self;
}

impl NativeType for f32 {
    const TY: ElementType = ElementType::F32;

    fn to_le(self) -> [u8; 4] {
        self.to_le_bytes()
    }

    fn from_le(bytes: &[u8]) -> Self {
        f32::from_le_bytes([bytes[0], bytes[1], bytes[2], bytes[3]])
    }
}

impl NativeType for i32 {
    const TY: ElementType = ElementType::S32;

    fn to_le(self) -> [u8; 4] {
        self.to_le_bytes()
    }

    fn from_le(bytes: &[u8]) -> Self {
        i32::from_le_bytes([bytes[0], bytes[1], bytes[2], bytes[3]])
    }
}

/// Array shape: element type + dimensions.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ArrayShape {
    ty: ElementType,
    dims: Vec<i64>,
}

impl ArrayShape {
    pub fn ty(&self) -> ElementType {
        self.ty
    }

    pub fn dims(&self) -> &[i64] {
        &self.dims
    }

    pub fn element_count(&self) -> usize {
        self.dims.iter().map(|&d| d as usize).product()
    }
}

/// A literal's shape: an array or a tuple of shapes.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum Shape {
    Array(ArrayShape),
    Tuple(Vec<Shape>),
}

// ---------------------------------------------------------------------------
// Literals
// ---------------------------------------------------------------------------

#[derive(Debug, Clone)]
enum Repr {
    Array {
        ty: ElementType,
        dims: Vec<usize>,
        data: Vec<u8>,
    },
    Tuple(Vec<Literal>),
}

/// A host-resident tensor value (array or tuple).
#[derive(Debug, Clone)]
pub struct Literal(Repr);

impl Literal {
    /// Rank-0 literal holding one value.
    pub fn scalar<T: NativeType>(v: T) -> Literal {
        Literal(Repr::Array {
            ty: T::TY,
            dims: Vec::new(),
            data: v.to_le().to_vec(),
        })
    }

    /// Zero-filled literal of the given shape (XLA's `CreateFromShape`
    /// zero-initializes).
    pub fn create_from_shape(ty: PrimitiveType, dims: &[usize]) -> Literal {
        let ety = ty.element_type();
        let isz = ety.size_bytes().unwrap_or(4);
        let n: usize = dims.iter().product();
        Literal(Repr::Array {
            ty: ety,
            dims: dims.to_vec(),
            data: vec![0u8; n * isz],
        })
    }

    /// Literal from raw little-endian bytes; the byte count must match
    /// the shape exactly.
    pub fn create_from_shape_and_untyped_data(
        ty: ElementType,
        dims: &[usize],
        data: &[u8],
    ) -> Result<Literal> {
        let isz = ty
            .size_bytes()
            .ok_or_else(|| err(format!("unsupported element type {ty:?}")))?;
        let n: usize = dims.iter().product();
        if data.len() != n * isz {
            return Err(err(format!(
                "data size {} != {} elements x {} bytes for {ty:?}{dims:?}",
                data.len(),
                n,
                isz
            )));
        }
        Ok(Literal(Repr::Array {
            ty,
            dims: dims.to_vec(),
            data: data.to_vec(),
        }))
    }

    pub fn shape(&self) -> Result<Shape> {
        match &self.0 {
            Repr::Array { ty, dims, .. } => Ok(Shape::Array(ArrayShape {
                ty: *ty,
                dims: dims.iter().map(|&d| d as i64).collect(),
            })),
            Repr::Tuple(xs) => Ok(Shape::Tuple(
                xs.iter()
                    .map(|x| x.shape())
                    .collect::<Result<Vec<_>>>()?,
            )),
        }
    }

    pub fn array_shape(&self) -> Result<ArrayShape> {
        match self.shape()? {
            Shape::Array(s) => Ok(s),
            Shape::Tuple(_) => Err(err("array_shape on a tuple literal")),
        }
    }

    /// Logical byte size (sum over tuple elements).
    pub fn size_bytes(&self) -> usize {
        match &self.0 {
            Repr::Array { data, .. } => data.len(),
            Repr::Tuple(xs) => xs.iter().map(|x| x.size_bytes()).sum(),
        }
    }

    pub fn to_vec<T: NativeType>(&self) -> Result<Vec<T>> {
        let (ty, _, data) = self.as_array()?;
        if ty != T::TY {
            return Err(err(format!("to_vec: literal is {ty:?}")));
        }
        Ok(data.chunks_exact(4).map(T::from_le).collect())
    }

    pub fn get_first_element<T: NativeType>(&self) -> Result<T> {
        let (ty, _, data) = self.as_array()?;
        if ty != T::TY {
            return Err(err(format!("get_first_element: literal is {ty:?}")));
        }
        if data.len() < 4 {
            return Err(err("get_first_element: empty literal"));
        }
        Ok(T::from_le(data))
    }

    /// Split a tuple literal into its elements (leaves this literal as
    /// an empty tuple).
    pub fn decompose_tuple(&mut self) -> Result<Vec<Literal>> {
        match &mut self.0 {
            Repr::Tuple(xs) => Ok(std::mem::take(xs)),
            Repr::Array { .. } => {
                Err(err("decompose_tuple on an array literal"))
            }
        }
    }

    fn as_array(&self) -> Result<(ElementType, &[usize], &[u8])> {
        match &self.0 {
            Repr::Array { ty, dims, data } => Ok((*ty, dims, data)),
            Repr::Tuple(_) => Err(err("expected array literal, got tuple")),
        }
    }
}

// ---------------------------------------------------------------------------
// Stub-HLO signatures
// ---------------------------------------------------------------------------

/// What an injected fault does when it fires (`fault` directive).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FaultKind {
    /// Every execution from the trigger call returns a stub error
    /// (a crashed device: the failure persists, it never heals).
    Fail,
    /// Every execution from the trigger call sleeps this many
    /// nanoseconds first (a wedged peer: values stay bit-identical).
    Stall(u64),
}

/// A parsed stub-HLO signature (stands in for a real `HloModuleProto`).
#[derive(Debug, Clone)]
pub struct HloModuleProto {
    name: String,
    seed: u64,
    acc: usize,
    group: usize,
    /// Busy delay in nanoseconds per execution (0 = none).
    cost_ns: u64,
    /// Cost drift: `Some((after_calls, drifted_ns))` switches the busy
    /// delay to `drifted_ns` from execution number `after_calls`
    /// (0-based) onward.  Values are unaffected.
    drift: Option<(u64, u64)>,
    /// Injected fault: `Some((kind, at_call))` fires from execution
    /// number `at_call` (0-based, counted per compiled executable like
    /// `drift`) onward.
    fault: Option<(FaultKind, u64)>,
    outs: Vec<(ElementType, Vec<usize>)>,
}

impl HloModuleProto {
    /// Parse a stub-HLO signature file (format in the crate docs).
    pub fn from_text_file(path: &Path) -> Result<HloModuleProto> {
        let text = std::fs::read_to_string(path)
            .map_err(|e| err(format!("reading {}: {e}", path.display())))?;
        Self::parse(&text)
            .map_err(|e| err(format!("{}: {}", path.display(), e.0)))
    }

    /// Parse stub-HLO signature text.
    pub fn parse(text: &str) -> Result<HloModuleProto> {
        let mut lines = text
            .lines()
            .map(str::trim)
            .filter(|l| !l.is_empty() && !l.starts_with('#'));
        match lines.next() {
            Some("stub-hlo v1") => {}
            other => {
                return Err(err(format!(
                    "expected 'stub-hlo v1' header, got {other:?}"
                )))
            }
        }
        let mut name = String::new();
        let mut seed = 0u64;
        let mut acc = 0usize;
        let mut group = 0usize;
        let mut cost_ns = 0u64;
        let mut drift = None;
        let mut fault = None;
        let mut outs = Vec::new();
        for line in lines {
            let mut it = line.split_whitespace();
            let key = it.next().unwrap_or("");
            let val = it.next().unwrap_or("");
            if it.next().is_some() {
                return Err(err(format!("trailing tokens in line '{line}'")));
            }
            match key {
                "module" => name = val.to_string(),
                "seed" => {
                    seed = val
                        .parse()
                        .map_err(|e| err(format!("bad seed '{val}': {e}")))?
                }
                "acc" => {
                    acc = val
                        .parse()
                        .map_err(|e| err(format!("bad acc '{val}': {e}")))?
                }
                "group" => {
                    group = val
                        .parse()
                        .map_err(|e| err(format!("bad group '{val}': {e}")))?
                }
                "cost" => {
                    cost_ns = val
                        .parse()
                        .map_err(|e| err(format!("bad cost '{val}': {e}")))?
                }
                "drift" => {
                    let (calls, ns) = val.split_once(':').ok_or_else(|| {
                        err(format!(
                            "bad drift '{val}': expected <calls>:<ns>"
                        ))
                    })?;
                    let calls = calls.parse().map_err(|e| {
                        err(format!("bad drift calls '{calls}': {e}"))
                    })?;
                    let ns = ns.parse().map_err(|e| {
                        err(format!("bad drift ns '{ns}': {e}"))
                    })?;
                    drift = Some((calls, ns));
                }
                "fault" => {
                    let (kind, at) = val.split_once('@').ok_or_else(|| {
                        err(format!(
                            "bad fault '{val}': expected <kind>@<call>"
                        ))
                    })?;
                    let kind = if kind == "fail" {
                        FaultKind::Fail
                    } else if let Some(ns) = kind.strip_prefix("stall-") {
                        FaultKind::Stall(ns.parse().map_err(|e| {
                            err(format!("bad fault stall ns '{ns}': {e}"))
                        })?)
                    } else {
                        return Err(err(format!(
                            "bad fault kind '{kind}': want fail or \
                             stall-<ns>"
                        )));
                    };
                    let at = at.parse().map_err(|e| {
                        err(format!("bad fault call '{at}': {e}"))
                    })?;
                    fault = Some((kind, at));
                }
                "out" => outs.push(parse_out(val)?),
                other => {
                    return Err(err(format!("unknown directive '{other}'")))
                }
            }
        }
        if outs.is_empty() {
            return Err(err("signature declares no outputs"));
        }
        if acc > 0 && group > 0 {
            return Err(err("acc and group are mutually exclusive"));
        }
        if acc > 0 && outs.len() != acc {
            return Err(err(format!(
                "acc {} but {} declared outputs",
                acc,
                outs.len()
            )));
        }
        Ok(HloModuleProto {
            name,
            seed,
            acc,
            group,
            cost_ns,
            drift,
            fault,
            outs,
        })
    }

    pub fn name(&self) -> &str {
        &self.name
    }

    /// Busy delay for execution number `call` (0-based): the base
    /// `cost` until the drift point, the drifted cost after it.
    fn cost_at(&self, call: u64) -> u64 {
        match self.drift {
            Some((after, ns)) if call >= after => ns,
            _ => self.cost_ns,
        }
    }
}

/// Parse an `out` operand like `f32[2,8,4]` or `s32[]` (scalar).
fn parse_out(tok: &str) -> Result<(ElementType, Vec<usize>)> {
    let open = tok
        .find('[')
        .ok_or_else(|| err(format!("missing '[' in out '{tok}'")))?;
    if !tok.ends_with(']') {
        return Err(err(format!("missing ']' in out '{tok}'")));
    }
    let ty = match &tok[..open] {
        "f32" => ElementType::F32,
        "s32" | "i32" => ElementType::S32,
        other => return Err(err(format!("unsupported out dtype '{other}'"))),
    };
    let inner = &tok[open + 1..tok.len() - 1];
    let dims = if inner.is_empty() {
        Vec::new()
    } else {
        inner
            .split(',')
            .map(|d| {
                d.trim()
                    .parse::<usize>()
                    .map_err(|e| err(format!("bad dim '{d}': {e}")))
            })
            .collect::<Result<Vec<_>>>()?
    };
    Ok((ty, dims))
}

/// A computation built from a signature (mirrors
/// `XlaComputation::from_proto` in the real crate).
#[derive(Debug, Clone)]
pub struct XlaComputation {
    proto: HloModuleProto,
}

impl XlaComputation {
    pub fn from_proto(proto: &HloModuleProto) -> XlaComputation {
        XlaComputation {
            proto: proto.clone(),
        }
    }

    pub fn name(&self) -> &str {
        self.proto.name()
    }
}

// ---------------------------------------------------------------------------
// Client / buffers / executables
// ---------------------------------------------------------------------------

struct ClientInner {
    platform: String,
}

/// One device context (`Rc`-based and single-threaded like the real
/// crate's client — one per worker thread).
#[derive(Clone)]
pub struct PjRtClient {
    inner: Rc<ClientInner>,
}

/// Placeholder device handle (`buffer_from_host_literal` takes
/// `Option<&PjRtDevice>`; the stub has exactly one device).
pub struct PjRtDevice;

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient> {
        Ok(PjRtClient {
            inner: Rc::new(ClientInner {
                platform: "stub-cpu".to_string(),
            }),
        })
    }

    pub fn platform_name(&self) -> String {
        self.inner.platform.clone()
    }

    /// Upload a host literal to a device buffer (a copy, in the stub).
    pub fn buffer_from_host_literal(
        &self,
        _device: Option<&PjRtDevice>,
        literal: &Literal,
    ) -> Result<PjRtBuffer> {
        Ok(PjRtBuffer {
            lit: literal.clone(),
        })
    }

    /// "Compile" a computation: capture its signature for execution.
    pub fn compile(&self, comp: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        Ok(PjRtLoadedExecutable {
            sig: comp.proto.clone(),
            client: self.clone(),
            calls: Cell::new(0),
        })
    }
}

/// A device-resident buffer (host bytes, in the stub).
#[derive(Debug, Clone)]
pub struct PjRtBuffer {
    lit: Literal,
}

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        Ok(self.lit.clone())
    }
}

/// A compiled executable: runs the deterministic stub semantics of its
/// signature.  Outputs come back as one tuple literal, matching the
/// `return_tuple=True` convention of the AOT pipeline.
pub struct PjRtLoadedExecutable {
    sig: HloModuleProto,
    client: PjRtClient,
    /// Executions so far — drives the `drift` directive.  A `Cell`
    /// suffices: the crate is single-threaded per worker (see above).
    calls: Cell<u64>,
}

impl PjRtLoadedExecutable {
    pub fn client(&self) -> PjRtClient {
        self.client.clone()
    }

    /// Execute with device-resident inputs; one replica of outputs.
    pub fn execute_b<B: Borrow<PjRtBuffer>>(
        &self,
        args: &[B],
    ) -> Result<Vec<Vec<PjRtBuffer>>> {
        let call = self.calls.get();
        self.calls.set(call + 1);
        let inputs: Vec<&Literal> =
            args.iter().map(|b| &b.borrow().lit).collect();
        let outs = execute_stub_at(&self.sig, call, &inputs)?;
        Ok(vec![vec![PjRtBuffer {
            lit: Literal(Repr::Tuple(outs)),
        }]])
    }
}

// ---------------------------------------------------------------------------
// Deterministic stub semantics
// ---------------------------------------------------------------------------

const FNV_OFFSET: u64 = 0xcbf2_9ce4_8422_2325;
const FNV_PRIME: u64 = 0x0000_0100_0000_01b3;

fn fnv_bytes(h: &mut u64, bytes: &[u8]) {
    for &b in bytes {
        *h = (*h ^ b as u64).wrapping_mul(FNV_PRIME);
    }
}

fn fnv_u64(h: &mut u64, x: u64) {
    fnv_bytes(h, &x.to_le_bytes());
}

fn hash_literal(h: &mut u64, lit: &Literal) {
    match &lit.0 {
        Repr::Array { ty, dims, data } => {
            fnv_bytes(h, &[ty.tag()]);
            fnv_u64(h, dims.len() as u64);
            for &d in dims {
                fnv_u64(h, d as u64);
            }
            fnv_bytes(h, data);
        }
        Repr::Tuple(xs) => {
            fnv_bytes(h, &[0xff]);
            fnv_u64(h, xs.len() as u64);
            for x in xs {
                hash_literal(h, x);
            }
        }
    }
}

fn hash_literals(lits: &[&Literal]) -> u64 {
    let mut h = FNV_OFFSET;
    for lit in lits {
        hash_literal(&mut h, lit);
    }
    h
}

fn splitmix(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E37_79B9_7F4A_7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

/// PRNG seed for output `j` of a call whose relevant inputs hash to
/// `h` — identical for `acc` and `group` modes, which is what makes a
/// concatenated p2 call equal the per-microbatch loop bit for bit.
fn out_seed(seed: u64, h: u64, j: usize) -> u64 {
    let mut s = seed
        ^ h.rotate_left(17)
        ^ (j as u64 + 1).wrapping_mul(0xD1B5_4A32_D192_ED03);
    splitmix(&mut s)
}

/// Uniform f32 in [-1, 1).
fn unit_f32(x: u64) -> f32 {
    ((x >> 40) as f32) / (1u32 << 24) as f32 * 2.0 - 1.0
}

/// Small integer-valued f32 in {-4, ..., 4}: exact under f32 addition
/// in any order (the commutative-accumulation property).
fn delta_f32(state: &mut u64) -> f32 {
    (splitmix(state) % 9) as f32 - 4.0
}

fn execute_stub(
    sig: &HloModuleProto,
    inputs: &[&Literal],
) -> Result<Vec<Literal>> {
    execute_stub_at(sig, 0, inputs)
}

/// [`execute_stub`] at a specific call index — the drift directive
/// selects the busy delay from the index; values never depend on it.
fn execute_stub_at(
    sig: &HloModuleProto,
    call: u64,
    inputs: &[&Literal],
) -> Result<Vec<Literal>> {
    match sig.fault {
        Some((FaultKind::Fail, at)) if call >= at => {
            return Err(err(format!(
                "{}: injected failure at call {call} (fault fail@{at})",
                sig.name
            )));
        }
        Some((FaultKind::Stall(ns), at)) if call >= at => {
            std::thread::sleep(std::time::Duration::from_nanos(ns));
        }
        _ => {}
    }
    let cost_ns = sig.cost_at(call);
    if cost_ns > 0 {
        // busy delay: sleeping (not spinning) lets concurrently-running
        // rank threads overlap, like compute on independent devices
        std::thread::sleep(std::time::Duration::from_nanos(cost_ns));
    }
    if sig.acc > 0 {
        execute_acc(sig, inputs)
    } else if sig.group > 0 {
        execute_group(sig, inputs)
    } else {
        execute_plain(sig, inputs)
    }
}

/// Plain mode: fill each declared output from a PRNG seeded by the
/// file seed, the hash of every input, and the output index.
fn execute_plain(
    sig: &HloModuleProto,
    inputs: &[&Literal],
) -> Result<Vec<Literal>> {
    let h = hash_literals(inputs);
    let mut outs = Vec::with_capacity(sig.outs.len());
    for (j, (ty, dims)) in sig.outs.iter().enumerate() {
        let n: usize = dims.iter().product();
        let mut state = out_seed(sig.seed, h, j);
        let mut data = Vec::with_capacity(n * 4);
        match ty {
            ElementType::F32 => {
                for _ in 0..n {
                    data.extend_from_slice(
                        &unit_f32(splitmix(&mut state)).to_le_bytes(),
                    );
                }
            }
            ElementType::S32 => {
                for _ in 0..n {
                    let v = (splitmix(&mut state) % 16) as i32;
                    data.extend_from_slice(&v.to_le_bytes());
                }
            }
            other => {
                return Err(err(format!(
                    "{}: unsupported output dtype {other:?}",
                    sig.name
                )))
            }
        }
        outs.push(Literal(Repr::Array {
            ty: *ty,
            dims: dims.clone(),
            data,
        }));
    }
    Ok(outs)
}

/// Accumulate mode: the last `acc` inputs are f32 accumulators; output
/// j = accumulator j + integer delta derived from the other inputs.
fn execute_acc(
    sig: &HloModuleProto,
    inputs: &[&Literal],
) -> Result<Vec<Literal>> {
    if inputs.len() < sig.acc {
        return Err(err(format!(
            "{}: {} inputs < {} accumulators",
            sig.name,
            inputs.len(),
            sig.acc
        )));
    }
    let split = inputs.len() - sig.acc;
    let h = hash_literals(&inputs[..split]);
    let mut outs = Vec::with_capacity(sig.acc);
    for (j, lit) in inputs[split..].iter().enumerate() {
        let (ty, dims, data) = lit.as_array()?;
        if ty != ElementType::F32 {
            return Err(err(format!(
                "{}: accumulator {j} is {ty:?}, want F32",
                sig.name
            )));
        }
        if dims != sig.outs[j].1.as_slice() {
            return Err(err(format!(
                "{}: accumulator {j} shape {dims:?} != declared {:?}",
                sig.name, sig.outs[j].1
            )));
        }
        let mut state = out_seed(sig.seed, h, j);
        let mut out = Vec::with_capacity(data.len());
        for chunk in data.chunks_exact(4) {
            let v = f32::from_le_bytes([chunk[0], chunk[1], chunk[2], chunk[3]]);
            out.extend_from_slice(&(v + delta_f32(&mut state)).to_le_bytes());
        }
        outs.push(Literal(Repr::Array {
            ty: ElementType::F32,
            dims: dims.to_vec(),
            data: out,
        }));
    }
    Ok(outs)
}

/// Grouped-sum mode: inputs arrive as consecutive groups of `group`
/// literals; each output sums one delta per group (seeded identically
/// to `acc` mode on the same group contents).
fn execute_group(
    sig: &HloModuleProto,
    inputs: &[&Literal],
) -> Result<Vec<Literal>> {
    if inputs.is_empty() || inputs.len() % sig.group != 0 {
        return Err(err(format!(
            "{}: {} inputs not a positive multiple of group {}",
            sig.name,
            inputs.len(),
            sig.group
        )));
    }
    let mut bufs: Vec<Vec<f32>> = Vec::with_capacity(sig.outs.len());
    for (ty, dims) in &sig.outs {
        if *ty != ElementType::F32 {
            return Err(err(format!(
                "{}: group outputs must be f32, got {ty:?}",
                sig.name
            )));
        }
        bufs.push(vec![0f32; dims.iter().product()]);
    }
    for group in inputs.chunks(sig.group) {
        let h = hash_literals(group);
        for (j, buf) in bufs.iter_mut().enumerate() {
            let mut state = out_seed(sig.seed, h, j);
            for v in buf.iter_mut() {
                *v += delta_f32(&mut state);
            }
        }
    }
    let outs = sig
        .outs
        .iter()
        .zip(bufs)
        .map(|((_, dims), buf)| {
            let mut data = Vec::with_capacity(buf.len() * 4);
            for v in buf {
                data.extend_from_slice(&v.to_le_bytes());
            }
            Literal(Repr::Array {
                ty: ElementType::F32,
                dims: dims.clone(),
                data,
            })
        })
        .collect();
    Ok(outs)
}

// ---------------------------------------------------------------------------

#[cfg(test)]
mod tests {
    use super::*;

    fn sig(text: &str) -> HloModuleProto {
        HloModuleProto::parse(text).expect("parse")
    }

    fn f32_lit(dims: &[usize], vals: &[f32]) -> Literal {
        let mut data = Vec::new();
        for v in vals {
            data.extend_from_slice(&v.to_le_bytes());
        }
        Literal::create_from_shape_and_untyped_data(
            ElementType::F32,
            dims,
            &data,
        )
        .unwrap()
    }

    #[test]
    fn parses_signature() {
        let s = sig("stub-hlo v1\nmodule t/fwd\nseed 7\nout f32[2,3]\nout s32[]\n");
        assert_eq!(s.name(), "t/fwd");
        assert_eq!(s.seed, 7);
        assert_eq!(s.outs.len(), 2);
        assert_eq!(s.outs[0], (ElementType::F32, vec![2, 3]));
        assert_eq!(s.outs[1], (ElementType::S32, vec![]));
    }

    #[test]
    fn rejects_bad_signatures() {
        assert!(HloModuleProto::parse("not a header\n").is_err());
        assert!(HloModuleProto::parse("stub-hlo v1\n").is_err());
        assert!(HloModuleProto::parse(
            "stub-hlo v1\nacc 1\ngroup 2\nout f32[1]\n"
        )
        .is_err());
        assert!(HloModuleProto::parse(
            "stub-hlo v1\nacc 2\nout f32[1]\n"
        )
        .is_err());
        assert!(HloModuleProto::parse("stub-hlo v1\nout f99[1]\n").is_err());
    }

    #[test]
    fn cost_directive_delays_but_never_changes_values() {
        let timed =
            sig("stub-hlo v1\nseed 3\ncost 20000000\nout f32[2,4]\n");
        assert_eq!(timed.cost_ns, 20_000_000);
        let free = sig("stub-hlo v1\nseed 3\nout f32[2,4]\n");
        let x = f32_lit(&[2], &[1.0, 2.0]);
        let t0 = std::time::Instant::now();
        let a = execute_stub(&timed, &[&x]).unwrap();
        let dt = t0.elapsed();
        let b = execute_stub(&free, &[&x]).unwrap();
        // same seed + inputs => bit-identical values, cost or not
        assert_eq!(
            a[0].to_vec::<f32>().unwrap(),
            b[0].to_vec::<f32>().unwrap()
        );
        assert!(
            dt >= std::time::Duration::from_millis(20),
            "cost 20ms not observed: {dt:?}"
        );
        assert!(HloModuleProto::parse(
            "stub-hlo v1\ncost banana\nout f32[1]\n"
        )
        .is_err());
    }

    #[test]
    fn drift_directive_switches_timing_after_n_calls_never_values() {
        let drifting = sig(
            "stub-hlo v1\nseed 3\ndrift 2:20000000\nout f32[2,4]\n",
        );
        assert_eq!(drifting.cost_ns, 0);
        assert_eq!(drifting.drift, Some((2, 20_000_000)));
        assert_eq!(drifting.cost_at(0), 0);
        assert_eq!(drifting.cost_at(1), 0);
        assert_eq!(drifting.cost_at(2), 20_000_000);
        assert_eq!(drifting.cost_at(99), 20_000_000);
        let free = sig("stub-hlo v1\nseed 3\nout f32[2,4]\n");
        let x = f32_lit(&[2], &[1.0, 2.0]);
        let pre = execute_stub_at(&drifting, 0, &[&x]).unwrap();
        let t0 = std::time::Instant::now();
        let post = execute_stub_at(&drifting, 2, &[&x]).unwrap();
        let dt = t0.elapsed();
        let base = execute_stub(&free, &[&x]).unwrap();
        // drift changes timing only — values stay bit-identical
        // across the drift point and match the cost-free signature
        assert_eq!(
            pre[0].to_vec::<f32>().unwrap(),
            post[0].to_vec::<f32>().unwrap()
        );
        assert_eq!(
            pre[0].to_vec::<f32>().unwrap(),
            base[0].to_vec::<f32>().unwrap()
        );
        assert!(
            dt >= std::time::Duration::from_millis(20),
            "drifted cost 20ms not observed: {dt:?}"
        );
    }

    #[test]
    fn drift_counter_lives_on_the_compiled_executable() {
        let proto = sig(
            "stub-hlo v1\nmodule d\nseed 9\ndrift 1:30000000\nout f32[2]\n",
        );
        let comp = XlaComputation::from_proto(&proto);
        let client = PjRtClient::cpu().unwrap();
        let exe = client.compile(&comp).unwrap();
        let buf = client
            .buffer_from_host_literal(None, &Literal::scalar(1.0f32))
            .unwrap();
        let run = |exe: &PjRtLoadedExecutable| {
            let t0 = std::time::Instant::now();
            exe.execute_b(&[&buf]).unwrap();
            t0.elapsed()
        };
        let first = run(&exe);
        let second = run(&exe);
        assert!(
            second >= std::time::Duration::from_millis(30),
            "call 1 should be past the drift point: {second:?}"
        );
        assert!(
            first < second,
            "call 0 ({first:?}) should be cheaper than drifted \
             call 1 ({second:?})"
        );
        // a freshly compiled executable starts un-drifted
        let fresh = client.compile(&comp).unwrap();
        assert!(run(&fresh) < std::time::Duration::from_millis(30));
    }

    #[test]
    fn fail_fault_fires_at_its_call_and_persists() {
        let s = sig("stub-hlo v1\nmodule f\nseed 2\nfault fail@2\nout f32[2]\n");
        assert_eq!(s.fault, Some((FaultKind::Fail, 2)));
        let x = f32_lit(&[2], &[1.0, 2.0]);
        let healthy = sig("stub-hlo v1\nmodule f\nseed 2\nout f32[2]\n");
        let want = execute_stub(&healthy, &[&x]).unwrap();
        // calls before the trigger behave exactly like the clean sig
        for call in 0..2 {
            let got = execute_stub_at(&s, call, &[&x]).unwrap();
            assert_eq!(
                got[0].to_vec::<f32>().unwrap(),
                want[0].to_vec::<f32>().unwrap()
            );
        }
        // at and after the trigger: a persistent error naming the call
        for call in [2, 3, 99] {
            let e = execute_stub_at(&s, call, &[&x]).unwrap_err();
            assert!(
                e.0.contains("injected failure")
                    && e.0.contains(&format!("call {call}")),
                "{e}"
            );
        }
    }

    #[test]
    fn stall_fault_delays_but_never_changes_values() {
        let s = sig(
            "stub-hlo v1\nmodule w\nseed 2\nfault stall-20000000@1\nout f32[2]\n",
        );
        assert_eq!(s.fault, Some((FaultKind::Stall(20_000_000), 1)));
        let x = f32_lit(&[2], &[1.0, 2.0]);
        let before = execute_stub_at(&s, 0, &[&x]).unwrap();
        let t0 = std::time::Instant::now();
        let after = execute_stub_at(&s, 1, &[&x]).unwrap();
        let dt = t0.elapsed();
        assert_eq!(
            before[0].to_vec::<f32>().unwrap(),
            after[0].to_vec::<f32>().unwrap()
        );
        assert!(
            dt >= std::time::Duration::from_millis(20),
            "stall 20ms not observed: {dt:?}"
        );
    }

    #[test]
    fn fault_counter_lives_on_the_compiled_executable() {
        let proto = sig(
            "stub-hlo v1\nmodule f\nseed 9\nfault fail@1\nout f32[2]\n",
        );
        let comp = XlaComputation::from_proto(&proto);
        let client = PjRtClient::cpu().unwrap();
        let exe = client.compile(&comp).unwrap();
        let buf = client
            .buffer_from_host_literal(None, &Literal::scalar(1.0f32))
            .unwrap();
        assert!(exe.execute_b(&[&buf]).is_ok(), "call 0 is clean");
        assert!(exe.execute_b(&[&buf]).is_err(), "call 1 trips");
        // a freshly compiled executable starts with a clean counter
        let fresh = client.compile(&comp).unwrap();
        assert!(fresh.execute_b(&[&buf]).is_ok());
    }

    #[test]
    fn rejects_malformed_fault() {
        for bad in [
            "stub-hlo v1\nfault fail\nout f32[1]\n",
            "stub-hlo v1\nfault explode@3\nout f32[1]\n",
            "stub-hlo v1\nfault stall-x@3\nout f32[1]\n",
            "stub-hlo v1\nfault fail@x\nout f32[1]\n",
            "stub-hlo v1\nfault fail @3\nout f32[1]\n",
        ] {
            assert!(HloModuleProto::parse(bad).is_err(), "{bad:?}");
        }
    }

    #[test]
    fn rejects_malformed_drift() {
        for bad in [
            "stub-hlo v1\ndrift 3\nout f32[1]\n",
            "stub-hlo v1\ndrift a:5\nout f32[1]\n",
            "stub-hlo v1\ndrift 3:b\nout f32[1]\n",
            "stub-hlo v1\ndrift 3:4:5\nout f32[1]\n",
            "stub-hlo v1\ndrift 3 5\nout f32[1]\n",
        ] {
            assert!(HloModuleProto::parse(bad).is_err(), "{bad:?}");
        }
    }

    #[test]
    fn plain_outputs_are_shape_correct_and_deterministic() {
        let s = sig("stub-hlo v1\nseed 3\nout f32[2,4]\nout s32[3]\n");
        let x = f32_lit(&[2], &[1.0, 2.0]);
        let a = execute_stub(&s, &[&x]).unwrap();
        let b = execute_stub(&s, &[&x]).unwrap();
        assert_eq!(a[0].to_vec::<f32>().unwrap(), b[0].to_vec::<f32>().unwrap());
        assert_eq!(a[0].array_shape().unwrap().dims(), &[2, 4]);
        assert_eq!(a[1].to_vec::<i32>().unwrap().len(), 3);
        assert!(a[1].to_vec::<i32>().unwrap().iter().all(|v| (0..16).contains(v)));
        // different input -> different output
        let y = f32_lit(&[2], &[1.0, 3.0]);
        let c = execute_stub(&s, &[&y]).unwrap();
        assert_ne!(a[0].to_vec::<f32>().unwrap(), c[0].to_vec::<f32>().unwrap());
    }

    #[test]
    fn acc_mode_is_order_independent() {
        let s = sig("stub-hlo v1\nseed 11\nacc 1\nout f32[4]\n");
        let a = f32_lit(&[3], &[1.0, 2.0, 3.0]);
        let b = f32_lit(&[3], &[4.0, 5.0, 6.0]);
        let zero = f32_lit(&[4], &[0.0; 4]);
        let apply = |acc: &Literal, x: &Literal| -> Literal {
            execute_stub(&s, &[x, acc]).unwrap().remove(0)
        };
        let ab = apply(&apply(&zero, &a), &b);
        let ba = apply(&apply(&zero, &b), &a);
        assert_eq!(ab.to_vec::<f32>().unwrap(), ba.to_vec::<f32>().unwrap());
    }

    #[test]
    fn group_mode_equals_acc_loop() {
        let loop_sig = sig("stub-hlo v1\nseed 5\nacc 1\nout f32[4]\n");
        let cat_sig = sig("stub-hlo v1\nseed 5\ngroup 1\nout f32[4]\n");
        let a = f32_lit(&[3], &[1.0, 2.0, 3.0]);
        let b = f32_lit(&[3], &[4.0, 5.0, 6.0]);
        let zero = f32_lit(&[4], &[0.0; 4]);
        let step1 = execute_stub(&loop_sig, &[&a, &zero]).unwrap().remove(0);
        let looped = execute_stub(&loop_sig, &[&b, &step1]).unwrap().remove(0);
        let grouped = execute_stub(&cat_sig, &[&a, &b]).unwrap().remove(0);
        assert_eq!(
            looped.to_vec::<f32>().unwrap(),
            grouped.to_vec::<f32>().unwrap()
        );
    }

    #[test]
    fn client_compile_execute_roundtrip() {
        let dir = std::env::temp_dir()
            .join(format!("xla-stub-test-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("t.hlo.txt");
        std::fs::write(&path, "stub-hlo v1\nmodule t\nseed 1\nout f32[2,2]\nout s32[2]\n")
            .unwrap();
        let proto = HloModuleProto::from_text_file(&path).unwrap();
        let comp = XlaComputation::from_proto(&proto);
        let client = PjRtClient::cpu().unwrap();
        assert_eq!(client.platform_name(), "stub-cpu");
        let exe = client.compile(&comp).unwrap();
        let input = Literal::scalar(42i32);
        let buf = client.buffer_from_host_literal(None, &input).unwrap();
        let mut replicas = exe.execute_b(&[buf]).unwrap();
        let mut tuple = replicas.remove(0).remove(0).to_literal_sync().unwrap();
        assert!(matches!(tuple.shape().unwrap(), Shape::Tuple(_)));
        let parts = tuple.decompose_tuple().unwrap();
        assert_eq!(parts.len(), 2);
        assert_eq!(parts[0].array_shape().unwrap().dims(), &[2, 2]);
        assert_eq!(parts[0].size_bytes(), 16);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn zero_literals_and_scalars() {
        let z = Literal::create_from_shape(PrimitiveType::F32, &[2, 3]);
        assert_eq!(z.size_bytes(), 24);
        assert!(z.to_vec::<f32>().unwrap().iter().all(|&v| v == 0.0));
        let s = Literal::scalar(1.5f32);
        assert_eq!(s.get_first_element::<f32>().unwrap(), 1.5);
        assert!(s.array_shape().unwrap().dims().is_empty());
        let i = Literal::scalar(-7i32);
        assert_eq!(i.get_first_element::<i32>().unwrap(), -7);
    }

    #[test]
    fn untyped_data_size_checked() {
        assert!(Literal::create_from_shape_and_untyped_data(
            ElementType::F32,
            &[2],
            &[0u8; 7]
        )
        .is_err());
    }
}
