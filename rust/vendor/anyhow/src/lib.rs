//! Offline drop-in subset of the `anyhow` error-handling crate.
//!
//! This workspace builds with no registry access (DESIGN.md §4 S14), so
//! the real `anyhow` cannot be fetched.  This vendored shim implements
//! exactly the surface the `twobp` crate uses:
//!
//! * [`Error`] — a context-chain error (no downcasting, no backtraces);
//! * [`Result<T>`] with the `Error` default;
//! * [`anyhow!`] / [`bail!`] macros;
//! * the [`Context`] extension trait (`.context` / `.with_context`) on
//!   `Result<_, E>` for both std errors and `Error` itself;
//! * `From<E: std::error::Error>` so `?` converts foreign errors.
//!
//! Formatting matches anyhow's conventions: `{}` shows the outermost
//! message, `{:#}` the full `outer: ...: root` chain.  Like the real
//! crate, `Error` deliberately does **not** implement
//! `std::error::Error` — that is what keeps the blanket `From`/`Context`
//! impls coherent.

use std::fmt;

/// A chain of messages, innermost (root cause) first.
pub struct Error {
    chain: Vec<String>,
}

impl Error {
    /// Construct from a single displayable message.
    pub fn msg<M: fmt::Display>(message: M) -> Error {
        Error { chain: vec![message.to_string()] }
    }

    /// Wrap with an outer context message.
    pub fn context<C: fmt::Display>(mut self, context: C) -> Error {
        self.chain.push(context.to_string());
        self
    }

    fn write_chain(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        for (i, msg) in self.chain.iter().rev().enumerate() {
            if i > 0 {
                write!(f, ": ")?;
            }
            write!(f, "{msg}")?;
        }
        Ok(())
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if f.alternate() {
            self.write_chain(f)
        } else {
            write!(f, "{}", self.chain.last().map(String::as_str).unwrap_or(""))
        }
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        self.write_chain(f)
    }
}

impl<E: std::error::Error + Send + Sync + 'static> From<E> for Error {
    fn from(e: E) -> Error {
        // capture the source chain, root cause first
        let mut chain = vec![e.to_string()];
        let mut src = e.source();
        while let Some(s) = src {
            chain.push(s.to_string());
            src = s.source();
        }
        chain.reverse();
        Error { chain }
    }
}

pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Construct an [`Error`] from a format string (or any `Display` value).
#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => { $crate::Error::msg(format!($msg)) };
    ($fmt:literal, $($arg:tt)*) => { $crate::Error::msg(format!($fmt, $($arg)*)) };
    ($msg:expr $(,)?) => { $crate::Error::msg($msg) };
}

/// `return Err(anyhow!(...))`.
#[macro_export]
macro_rules! bail {
    ($($t:tt)*) => { return Err($crate::anyhow!($($t)*)) };
}

mod private {
    /// Sealed conversion into [`crate::Error`].  Implemented for std
    /// errors and for `Error` itself; the two impls stay coherent
    /// because `Error` does not implement `std::error::Error`.
    pub trait IntoError {
        fn into_error(self) -> crate::Error;
    }

    impl<E: std::error::Error + Send + Sync + 'static> IntoError for E {
        fn into_error(self) -> crate::Error {
            crate::Error::from(self)
        }
    }

    impl IntoError for crate::Error {
        fn into_error(self) -> crate::Error {
            self
        }
    }
}

use private::IntoError;

/// `.context(...)` / `.with_context(|| ...)` on results.
pub trait Context<T>: Sized {
    fn context<C: fmt::Display>(self, context: C) -> Result<T, Error>;
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(
        self,
        f: F,
    ) -> Result<T, Error>;
}

impl<T, E: private::IntoError> Context<T> for Result<T, E> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T, Error> {
        self.map_err(|e| e.into_error().context(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(
        self,
        f: F,
    ) -> Result<T, Error> {
        self.map_err(|e| e.into_error().context(f()))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_err() -> std::io::Error {
        std::io::Error::new(std::io::ErrorKind::NotFound, "no such file")
    }

    #[test]
    fn macro_forms() {
        let a = anyhow!("plain");
        assert_eq!(format!("{a}"), "plain");
        let n = 3;
        let b = anyhow!("n = {n}");
        assert_eq!(format!("{b}"), "n = 3");
        let c = anyhow!("n = {}", 4);
        assert_eq!(format!("{c}"), "n = 4");
        let d = anyhow!(String::from("owned"));
        assert_eq!(format!("{d}"), "owned");
    }

    #[test]
    fn bail_returns_err() {
        fn f() -> Result<()> {
            bail!("boom {}", 1);
        }
        assert_eq!(format!("{}", f().unwrap_err()), "boom 1");
    }

    #[test]
    fn context_chains_and_alternate_format() {
        let e: Result<()> = Err(io_err()).context("reading manifest");
        let e = e.with_context(|| format!("loading preset {}", "bert-s"));
        let err = e.unwrap_err();
        assert_eq!(format!("{err}"), "loading preset bert-s");
        assert_eq!(
            format!("{err:#}"),
            "loading preset bert-s: reading manifest: no such file"
        );
    }

    #[test]
    fn question_mark_converts_foreign_errors() {
        fn f() -> Result<String> {
            let s = std::str::from_utf8(&[0xff])?;
            Ok(s.to_string())
        }
        assert!(f().is_err());
    }

    #[test]
    fn context_on_anyhow_result() {
        let e: Result<()> = Err(anyhow!("root"));
        let err = e.context("outer").unwrap_err();
        assert_eq!(format!("{err:#}"), "outer: root");
    }
}
