//! Bench: regenerate Fig 5 (memory-efficient eager-p2 1F1B-2 variant).
//! `cargo bench --bench fig5_memory_schedule [-- --steps N]`
fn main() {
    let steps = std::env::args().skip_while(|a| a != "--steps").nth(1)
        .and_then(|s| s.parse().ok()).unwrap_or(2);
    match twobp::experiments::fig5(
        steps,
        &std::env::var("TWOBP_BENCH_PRESET").unwrap_or_else(|_| "bert-s".into()),
    ) {
        Ok(s) => print!("{s}"),
        Err(e) => { eprintln!("fig5 failed: {e:#}"); std::process::exit(1); }
    }
}
