//! Bench: `twobp serve` batch throughput — jobs/sec through the full
//! service path (line parse → deadline/priority scheduling → engine op
//! → sorted-key JSON response), plus the residency win: repeated tune
//! queries served from the fingerprint cache.
//!
//! ```text
//! cargo bench --bench serve_throughput [-- --quick]
//!     [-- --baseline BENCH_baseline.json]
//!     [-- --write-baseline BENCH_baseline.json]
//! ```
//!
//! The batch is deterministic: one calibrate, then one score job per
//! distinct plan in the generator corpus (every (kind, 2bp) combo ×
//! the planner's microbatch grid at N=4), each with a distinct
//! deadline so the heap is exercised, then a shutdown.  Every response
//! is asserted `ok` before timing.  A second timed phase submits the
//! same small tune job repeatedly against a resident engine: after the
//! first miss every response is a recorded cache hit, measuring what
//! residency buys over re-searching.
//!
//! Results append to `BENCH_serve.json` at the repo root.
//! **Regression gate**: with `--baseline <file>`, measured jobs/sec is
//! compared against `serve_{quick,full}_jobs_per_sec` and the process
//! exits non-zero on a >20% regression — the same rule as the sweep
//! and planner benches.  `--write-baseline <file>` refreshes the entry.

use std::path::Path;
use std::time::Instant;

use twobp::experiments::sweep::combos;
use twobp::planner::beam::microbatch_grid;
use twobp::schedule::{generate, plan_io};
use twobp::serve::{run_batch, Engine};
use twobp::util::args::Args;
use twobp::util::json::{obj, Json};
use twobp::util::stats::{summarize, BenchRecorder};

/// The serve batch: calibrate → one score per distinct corpus plan
/// (distinct ids and deadlines) → shutdown.
fn batch(n_ranks: usize) -> String {
    let mut lines = vec![format!(
        r#"{{"op":"calibrate","id":"c","name":"prof","ranks":{n_ranks},"deadline":0}}"#
    )];
    let mut i = 0usize;
    for (kind, two_bp) in combos() {
        for &m in &microbatch_grid(n_ranks, 4 * n_ranks) {
            let p = generate(kind, two_bp, n_ranks, m, false);
            // Json::Str handles the JSON escaping of the plan text.
            let text = Json::Str(plan_io::to_text(&p)).to_string();
            lines.push(format!(
                r#"{{"op":"score","id":"s{i}","plan":{text},"profile":"prof","deadline":{d}}}"#,
                d = i + 1
            ));
            i += 1;
        }
    }
    lines.push(format!(
        r#"{{"op":"shutdown","id":"z","deadline":{}}}"#,
        i + 1
    ));
    lines.join("\n")
}

fn main() {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let args = Args::parse(&argv, &["quick"]);
    let quick = args.has("quick");

    let input = batch(4);
    let jobs = input.lines().count();
    println!(
        "serve_throughput: {jobs} jobs/batch (1 calibrate + {} scores + \
         1 shutdown, distinct deadlines)\n",
        jobs - 2
    );

    // -- agreement: the whole batch drains ok before timing ----------------
    {
        let mut e = Engine::new(0);
        let (resp, shutdown) =
            run_batch(&mut e, &input, &mut None).expect("batch");
        assert!(shutdown, "shutdown job must drain the batch");
        assert_eq!(resp.len(), jobs);
        for r in &resp {
            assert!(r.contains("\"ok\":true"), "job failed: {r}");
        }
    }

    // -- timing: full batches against fresh engines ------------------------
    let reps = if quick { 3 } else { 5 };
    let run_once = || {
        let mut e = Engine::new(0);
        let (resp, _) = run_batch(&mut e, &input, &mut None).expect("batch");
        resp.len()
    };
    run_once(); // warmup
    let mut jps = Vec::with_capacity(reps);
    for _ in 0..reps {
        let t0 = Instant::now();
        let n = run_once();
        let dt = t0.elapsed().as_secs_f64();
        jps.push(n as f64 / dt);
    }
    let jps_s = summarize(&jps);
    println!(
        "  batch drain        : {:>10.0} jobs/s (± {:.0}, n={reps})",
        jps_s.mean, jps_s.std
    );

    // -- residency: repeated tunes served from the result cache ------------
    let hits = if quick { 50 } else { 200 };
    let mut e = Engine::new(0);
    let tune_line = r#"{"op":"tune","ranks":4,"beam":2,"gens":1,"mutations":2}"#;
    let (first, _) =
        run_batch(&mut e, tune_line, &mut None).expect("tune miss");
    assert!(first[0].contains("\"cache\":\"miss\""), "{first:?}");
    let hit_input = vec![tune_line; hits].join("\n");
    let t0 = Instant::now();
    let (resp, _) = run_batch(&mut e, &hit_input, &mut None).expect("hits");
    let hit_dt = t0.elapsed().as_secs_f64();
    assert_eq!(resp.len(), hits);
    for r in &resp {
        assert!(r.contains("\"cache\":\"hit\""), "expected a hit: {r}");
    }
    let hits_per_sec = hits as f64 / hit_dt;
    println!(
        "  cached tune serves : {:>10.0} hits/s ({hits} repeats of one \
         tune; cache hits recorded: {})\n",
        hits_per_sec,
        e.metrics.counter("serve.cache_hits")
    );

    // -- record the trajectory at the repo root ---------------------------
    let repo_root = Path::new(env!("CARGO_MANIFEST_DIR"))
        .parent()
        .expect("crate lives under <repo>/rust");
    let mut rec = BenchRecorder::open(&repo_root.join("BENCH_serve.json"));
    rec.record(
        "serve_batch",
        obj(vec![
            ("jobs_per_batch", Json::Num(jobs as f64)),
            ("jobs_per_sec", Json::Num(jps_s.mean)),
            ("cached_tune_hits_per_sec", Json::Num(hits_per_sec)),
            ("quick", Json::Bool(quick)),
        ]),
    );
    let mode_key = if quick {
        "serve_quick_jobs_per_sec"
    } else {
        "serve_full_jobs_per_sec"
    };
    rec.record_summary(mode_key, &jps_s);
    match rec.write() {
        Ok(()) => {
            println!("  wrote {}", repo_root.join("BENCH_serve.json").display())
        }
        Err(e) => {
            eprintln!("  warning: could not write BENCH_serve.json: {e}")
        }
    }

    // -- regression gate vs a committed baseline ---------------------------
    if let Some(path) = args.get("write-baseline") {
        let mut base = BenchRecorder::open(Path::new(path));
        base.record(mode_key, Json::Num(jps_s.mean));
        match base.write() {
            Ok(()) => {
                println!("  wrote {mode_key} = {:.0} to {path}", jps_s.mean)
            }
            Err(e) => {
                eprintln!("FAIL: could not write baseline {path}: {e}");
                std::process::exit(1);
            }
        }
    }
    if let Some(path) = args.get("baseline") {
        let committed = std::fs::read_to_string(path)
            .ok()
            .and_then(|t| Json::parse(&t).ok())
            .and_then(|v| v.get(mode_key).and_then(|x| x.as_f64()));
        match committed {
            None => {
                eprintln!(
                    "FAIL: baseline {path} is missing a numeric \
                     '{mode_key}' entry"
                );
                std::process::exit(1);
            }
            Some(committed) => {
                let ratio = jps_s.mean / committed;
                println!(
                    "  regression gate [{mode_key}]: {:.0} jobs/s vs \
                     baseline {committed:.0} ({ratio:.2}x, fail below \
                     0.80x)",
                    jps_s.mean
                );
                if ratio < 0.8 {
                    eprintln!(
                        "FAIL: {mode_key} regressed >20% vs {path} \
                         ({:.0} < 0.8 x {committed:.0} jobs/s)",
                        jps_s.mean
                    );
                    std::process::exit(1);
                }
            }
        }
    }
}
