//! Bench: planner candidate-evaluation throughput — the Tier A scoring
//! fast path (`sim::score_plan` + one reused `Scratch`) against the
//! Tier B per-candidate `sim::eval_plan` baseline (full `validate` +
//! span-recording simulate + budget check), which is exactly what the
//! beam search paid per candidate before the two-tier split.
//!
//! ```text
//! cargo bench --bench planner_throughput [-- --quick]
//!     [-- --baseline BENCH_baseline.json]
//!     [-- --write-baseline BENCH_baseline.json]
//! ```
//!
//! The corpus is deterministic: the llama_like(4) tune profile's seed
//! pool (every generator combo × the planner's microbatch grid) plus
//! seeded chains of validated local moves — the same plan shapes the
//! beam actually evaluates, including valid-but-deadlocked mutants
//! (both paths must reject those identically).  Before timing, every
//! candidate is evaluated both ways and the paths are asserted
//! bit-identical on makespan/bubble/peak/fits.
//!
//! Acceptance target (ISSUE 3): the scoring path sustains **>= 3x**
//! candidates/sec over the `eval_plan` baseline (asserted in full
//! mode; quick mode prints it).  Results append to `BENCH_planner.json`
//! at the **repo root** (resolved via `CARGO_MANIFEST_DIR`, so the
//! file lands in the same place regardless of the invocation cwd) —
//! the cross-PR perf trajectory for planner workloads.
//!
//! **Regression gate**: with `--baseline <file>`, the measured scoring
//! cands/sec mean is compared against the committed entry for the
//! current mode (`planner_quick_cands_per_sec` /
//! `planner_full_cands_per_sec`) and the process exits non-zero on a
//! >20% regression — the same rule as `sweep_throughput`.
//! `--write-baseline <file>` refreshes that entry in place.
//!
//! The robust Monte-Carlo objective (`sim::score_plan_robust`, ISSUE 6)
//! is timed the same way over the live corpus: K perturbation draws per
//! candidate, metric = draws/sec.  A draw is one cost-model copy +
//! perturbation + `score_plan`, so its throughput should track the
//! clean scoring path — the gate keys are
//! `planner_robust_{quick,full}_trials_per_sec`.
//!
//! The partition co-search (ISSUE 10) adds its own hot path: the
//! boundary hill-climb re-scores the incumbent plan under every
//! neighbor partition — one `ModelProfile::roll_up` + Tier A
//! `score_plan` per neighbor.  That primitive is timed over a
//! deterministic partition fan (metric = rolls/sec, gate keys
//! `planner_cosearch_{quick,full}_rolls_per_sec`), and one end-to-end
//! `co_search` run is reported (not gated — it is dominated by the
//! inner beams already gated above).

use std::collections::BTreeSet;
use std::path::Path;
use std::time::Instant;

use twobp::experiments::sweep::combos;
use twobp::metrics::observer::NullObserver;
use twobp::planner::beam::microbatch_grid;
use twobp::planner::{
    co_search, moves, tune, BeamConfig, CoSearchConfig, ModelProfile,
    TuneProfile,
};
use twobp::schedule::{
    generate, validate::validate, Partition, Plan, ScheduleKind,
};
use twobp::sim::{eval_plan, score_plan, score_plan_robust, Perturbation,
                 RobustScratch, Scratch};
use twobp::util::args::Args;
use twobp::util::json::{obj, Json};
use twobp::util::prng::SplitMix64;
use twobp::util::stats::{fmt_duration, summarize, BenchRecorder};

const GIB: u64 = 1 << 30;

/// Deterministic candidate corpus: every (kind, 2bp) seed at the
/// planner's own microbatch grid (`beam::microbatch_grid` at its
/// default 4N cap — the bench can't drift from what the beam seeds),
/// plus a chain of `chain_len` validated local moves from each seed.
/// Dedup by fingerprint, like the beam.
fn corpus(n_ranks: usize, chain_len: usize, seed: u64) -> Vec<Plan> {
    let mut plans: Vec<Plan> = Vec::new();
    let mut seen: BTreeSet<u64> = BTreeSet::new();
    for (kind, two_bp) in combos() {
        for &m in &microbatch_grid(n_ranks, 4 * n_ranks) {
            let p = generate(kind, two_bp, n_ranks, m, false);
            validate(&p).expect("generator seed must validate");
            if seen.insert(p.fingerprint()) {
                plans.push(p);
            }
        }
    }
    let mut rng = SplitMix64::new(seed);
    let seeds: Vec<Plan> = plans.clone();
    for base in &seeds {
        let mut cur = base.clone();
        for _ in 0..chain_len {
            if let Some((next, _mv)) = moves::mutate(&cur, &mut rng) {
                if seen.insert(next.fingerprint()) {
                    plans.push(next.clone());
                }
                cur = next;
            }
        }
    }
    plans
}

fn main() {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let args = Args::parse(&argv, &["quick"]);
    let quick = args.has("quick");

    let profile = TuneProfile::llama_like(4);
    let budget = Some(6 * GIB); // binds for deep-stash candidates
    let chain_len = if quick { 12 } else { 40 };
    let plans = corpus(4, chain_len, 0x2B9_0003);
    println!(
        "planner_throughput: {} candidates (llama-like profile, N=4, \
         budget 6 GiB/rank, mutation chains of {chain_len})\n",
        plans.len()
    );

    // -- agreement: both paths identical per candidate, before timing ------
    let mut scratch = Scratch::new();
    let mut live = 0usize;
    let mut dead = 0usize;
    for (i, p) in plans.iter().enumerate() {
        let base = eval_plan(p, &profile.costs, Some(&profile.mem), budget);
        let fast = score_plan(p, &profile.costs, Some(&profile.mem), budget,
                              &mut scratch);
        match (base, fast) {
            (Err(_), Err(_)) => dead += 1,
            (Ok(b), Ok(f)) => {
                assert_eq!(
                    b.result.makespan.to_bits(),
                    f.makespan.to_bits(),
                    "candidate {i} ({}): makespan diverged",
                    p.describe()
                );
                assert_eq!(
                    b.result.bubble_ratio.to_bits(),
                    f.bubble_ratio.to_bits(),
                    "candidate {i}: bubble diverged"
                );
                assert_eq!(b.max_peak, f.max_peak,
                           "candidate {i}: peak diverged");
                assert_eq!(b.fits, f.fits, "candidate {i}: fits diverged");
                live += 1;
            }
            (b, f) => panic!(
                "candidate {i} ({}): paths disagree on rejection \
                 (baseline err: {}, scored err: {})",
                p.describe(),
                b.is_err(),
                f.is_err()
            ),
        }
    }
    println!(
        "  agreement: all {} candidates bit-identical across paths \
         ({live} live, {dead} deadlocked — rejected by both)\n",
        plans.len()
    );

    // -- timing ------------------------------------------------------------
    let reps = if quick { 3 } else { 5 };
    let run_baseline = || {
        for p in &plans {
            let _ = eval_plan(p, &profile.costs, Some(&profile.mem), budget);
        }
    };
    let run_scored = |scratch: &mut Scratch| {
        for p in &plans {
            let _ = score_plan(p, &profile.costs, Some(&profile.mem), budget,
                               scratch);
        }
    };

    run_baseline(); // warmup
    let mut base_cps = Vec::with_capacity(reps);
    for _ in 0..reps {
        let t0 = Instant::now();
        run_baseline();
        let dt = t0.elapsed().as_secs_f64();
        base_cps.push(plans.len() as f64 / dt);
    }
    run_scored(&mut scratch); // warmup (and buffer growth)
    let mut fast_cps = Vec::with_capacity(reps);
    for _ in 0..reps {
        let t0 = Instant::now();
        run_scored(&mut scratch);
        let dt = t0.elapsed().as_secs_f64();
        fast_cps.push(plans.len() as f64 / dt);
    }

    let base_s = summarize(&base_cps);
    let fast_s = summarize(&fast_cps);
    let speedup = fast_s.mean / base_s.mean;
    println!(
        "  eval_plan baseline : {:>10.0} cands/s (± {:.0}, n={reps})",
        base_s.mean, base_s.std
    );
    println!(
        "  score_plan+scratch : {:>10.0} cands/s (± {:.0}, n={reps})",
        fast_s.mean, fast_s.std
    );
    println!(
        "\n  speedup: {speedup:.2}x  (acceptance target >= 3x)\n"
    );

    // -- robust scoring: K Monte-Carlo draws per candidate ------------------
    // timed over the *live* corpus only — a deadlocked plan errors on
    // its first draw, which would inflate a draws/sec figure
    let live_plans: Vec<&Plan> = plans
        .iter()
        .filter(|p| {
            score_plan(p, &profile.costs, Some(&profile.mem), budget,
                       &mut scratch)
            .is_ok()
        })
        .collect();
    let pert = Perturbation {
        jitter: 0.05,
        stragglers: vec![(1, 1.5)],
        ..Perturbation::default()
    };
    let trials = if quick { 8 } else { 16 };
    let mut rscratch = RobustScratch::new();
    let run_robust = |rscratch: &mut RobustScratch| {
        for p in &live_plans {
            let _ = score_plan_robust(p, &profile.costs, Some(&profile.mem),
                                      budget, &pert, trials, rscratch);
        }
    };
    run_robust(&mut rscratch); // warmup (and buffer growth)
    let mut robust_tps = Vec::with_capacity(reps);
    for _ in 0..reps {
        let t0 = Instant::now();
        run_robust(&mut rscratch);
        let dt = t0.elapsed().as_secs_f64();
        robust_tps.push((live_plans.len() * trials) as f64 / dt);
    }
    let robust_s = summarize(&robust_tps);
    println!(
        "  score_plan_robust  : {:>10.0} draws/s ({} draws/candidate \
         over {} live plans; per-draw cost {:.2}x a clean score)\n",
        robust_s.mean,
        trials,
        live_plans.len(),
        fast_s.mean / robust_s.mean.max(1e-9)
    );

    // -- co-search hot path: roll-up + Tier A re-score per neighbor --------
    // the hill-climb's inner loop: one ModelProfile::roll_up + one
    // score_plan per neighbor partition, schedule held fixed
    let layers = 8;
    let mut layer_model =
        ModelProfile::from_profile(&TuneProfile::llama_like(layers));
    layer_model.allreduce_per_byte = 2e-11;
    layer_model.layers[0].fwd *= 3.0;
    // every contiguous 2-stage split, plus the balanced 4-stage split
    // and its full neighbor fan — exactly what the climb re-scores
    let mut parts: Vec<Partition> = (1..layers)
        .map(|c| Partition { cuts: vec![0, c, layers], dp: 1 })
        .collect();
    let b4 = Partition::balanced(layers, 4, 1);
    parts.extend(moves::partition_neighbors(&b4));
    parts.push(b4);
    let plan2 = generate(ScheduleKind::OneF1B1, true, 2, 8, false);
    let plan4 = generate(ScheduleKind::OneF1B1, true, 4, 8, false);
    let roll_iters = if quick { 200 } else { 600 };
    let run_rolls = |scratch: &mut Scratch| {
        for _ in 0..roll_iters {
            for part in &parts {
                let rolled =
                    layer_model.roll_up(part).expect("valid partition");
                let plan =
                    if part.n_stages() == 2 { &plan2 } else { &plan4 };
                let _ = score_plan(plan, &rolled.costs, Some(&rolled.mem),
                                   budget, scratch);
            }
        }
    };
    run_rolls(&mut scratch); // warmup
    let mut roll_rps = Vec::with_capacity(reps);
    for _ in 0..reps {
        let t0 = Instant::now();
        run_rolls(&mut scratch);
        let dt = t0.elapsed().as_secs_f64();
        roll_rps.push((parts.len() * roll_iters) as f64 / dt);
    }
    let roll_s = summarize(&roll_rps);
    println!(
        "  co-search roll+score: {:>7.0} rolls/s ({} partitions × \
         {roll_iters} iters over the hill-climb's re-score path)",
        roll_s.mean,
        parts.len()
    );

    // one end-to-end joint search (reported, not gated: dominated by
    // the inner beams, whose throughput the gates above already cover)
    let t0 = Instant::now();
    let cs = co_search(
        &layer_model,
        &CoSearchConfig::new(
            4,
            BeamConfig {
                budget_bytes: budget,
                beam_width: 4,
                generations: 3,
                mutations_per_parent: 3,
                seed: 0x2B9,
                ..BeamConfig::default()
            },
        ),
        &mut NullObserver,
    )
    .expect("co_search");
    let cs_dt = t0.elapsed().as_secs_f64();
    println!(
        "  co-search end-to-end: {} cells in {} (winner dp={} pp={}, \
         {} migrations)\n",
        cs.cells.len() + cs.infeasible.len(),
        fmt_duration(cs_dt),
        cs.best().dp,
        cs.best().pp,
        cs.best().migrations
    );

    // -- end-to-end: a small tune() ride on the fast path -----------------
    let t0 = Instant::now();
    let report = tune(
        &profile,
        4,
        &BeamConfig {
            budget_bytes: budget,
            generations: 4,
            seed: 0x2B9,
            ..BeamConfig::default()
        },
    )
    .expect("tune");
    let tune_dt = t0.elapsed().as_secs_f64();
    println!(
        "  tune end-to-end: {} candidates in {} ({:.0} cands/s incl. \
         search overhead)\n",
        report.evaluated,
        fmt_duration(tune_dt),
        report.evaluated as f64 / tune_dt
    );

    // -- record the trajectory at the repo root ---------------------------
    let repo_root = Path::new(env!("CARGO_MANIFEST_DIR"))
        .parent()
        .expect("crate lives under <repo>/rust");
    let mut rec = BenchRecorder::open(&repo_root.join("BENCH_planner.json"));
    rec.record("planner_eval", obj(vec![
        ("candidates", Json::Num(plans.len() as f64)),
        ("live", Json::Num(live as f64)),
        ("deadlocked", Json::Num(dead as f64)),
        ("baseline_cands_per_sec", Json::Num(base_s.mean)),
        ("scored_cands_per_sec", Json::Num(fast_s.mean)),
        ("speedup", Json::Num(speedup)),
        ("quick", Json::Bool(quick)),
    ]));
    rec.record("tune_end_to_end", obj(vec![
        ("evaluated", Json::Num(report.evaluated as f64)),
        ("seconds", Json::Num(tune_dt)),
        ("cands_per_sec", Json::Num(report.evaluated as f64 / tune_dt)),
    ]));
    rec.record("planner_robust", obj(vec![
        ("live_candidates", Json::Num(live_plans.len() as f64)),
        ("trials_per_candidate", Json::Num(trials as f64)),
        ("trials_per_sec", Json::Num(robust_s.mean)),
        ("per_draw_cost_vs_clean",
         Json::Num(fast_s.mean / robust_s.mean.max(1e-9))),
        ("quick", Json::Bool(quick)),
    ]));
    rec.record("planner_cosearch", obj(vec![
        ("partitions", Json::Num(parts.len() as f64)),
        ("roll_iters", Json::Num(roll_iters as f64)),
        ("rolls_per_sec", Json::Num(roll_s.mean)),
        ("cosearch_cells", Json::Num(
            (cs.cells.len() + cs.infeasible.len()) as f64)),
        ("cosearch_seconds", Json::Num(cs_dt)),
        ("quick", Json::Bool(quick)),
    ]));
    let mode_key = if quick {
        "planner_quick_cands_per_sec"
    } else {
        "planner_full_cands_per_sec"
    };
    let robust_key = if quick {
        "planner_robust_quick_trials_per_sec"
    } else {
        "planner_robust_full_trials_per_sec"
    };
    let cosearch_key = if quick {
        "planner_cosearch_quick_rolls_per_sec"
    } else {
        "planner_cosearch_full_rolls_per_sec"
    };
    rec.record_summary(mode_key, &fast_s);
    rec.record_summary(robust_key, &robust_s);
    rec.record_summary(cosearch_key, &roll_s);
    match rec.write() {
        Ok(()) => println!("  wrote {}", repo_root
            .join("BENCH_planner.json").display()),
        Err(e) => eprintln!("  warning: could not write BENCH_planner.json: \
                             {e}"),
    }

    // -- regression gate vs a committed baseline ---------------------------
    let gates = [(mode_key, fast_s.mean, "cands/s"),
                 (robust_key, robust_s.mean, "draws/s"),
                 (cosearch_key, roll_s.mean, "rolls/s")];
    if let Some(path) = args.get("write-baseline") {
        let mut base = BenchRecorder::open(Path::new(path));
        for (key, mean, _) in gates {
            base.record(key, Json::Num(mean));
        }
        match base.write() {
            Ok(()) => {
                for (key, mean, _) in gates {
                    println!("  wrote {key} = {mean:.0} to {path}");
                }
            }
            Err(e) => {
                eprintln!("FAIL: could not write baseline {path}: {e}");
                std::process::exit(1);
            }
        }
    }
    if let Some(path) = args.get("baseline") {
        let json = std::fs::read_to_string(path)
            .ok()
            .and_then(|t| Json::parse(&t).ok());
        for (key, mean, unit) in gates {
            let committed = json
                .as_ref()
                .and_then(|v| v.get(key).and_then(|x| x.as_f64()));
            match committed {
                None => {
                    eprintln!(
                        "FAIL: baseline {path} is missing a numeric \
                         '{key}' entry"
                    );
                    std::process::exit(1);
                }
                Some(committed) => {
                    let ratio = mean / committed;
                    println!(
                        "  regression gate [{key}]: {mean:.0} {unit} vs \
                         baseline {committed:.0} ({ratio:.2}x, fail \
                         below 0.80x)"
                    );
                    if ratio < 0.8 {
                        eprintln!(
                            "FAIL: {key} regressed >20% vs {path} \
                             ({mean:.0} < 0.8 x {committed:.0} {unit})"
                        );
                        std::process::exit(1);
                    }
                }
            }
        }
    }

    if !quick && speedup < 3.0 {
        eprintln!(
            "FAIL: scoring fast path speedup {speedup:.2}x below the 3x \
             acceptance target"
        );
        std::process::exit(1);
    }
}
