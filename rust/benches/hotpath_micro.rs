//! Microbenchmarks of the L3 hot path (the §Perf instrument): executable
//! dispatch, host<->literal conversion, channel transfer, stash churn.
//!
//! `cargo bench --features pjrt --bench hotpath_micro`
//!
//! The coordinator must never be the bottleneck (DESIGN.md §9): each of
//! these costs is compared against the smallest real op (a tiny stage's
//! fwd ≈ hundreds of µs), and the bench fails loudly if L3 overhead gets
//! within an order of magnitude of it.  Results are also appended to
//! `BENCH_sim.json` so the perf trajectory is tracked across PRs.

use std::path::Path;

use twobp::models::{DType, Manifest};
use twobp::pipeline::comm::link;
use twobp::runtime::{scalar_i32, Device, HostTensor, ZeroCache};
use twobp::util::stats::{bench, fmt_duration, summarize, BenchRecorder};

fn main() -> anyhow::Result<()> {
    println!("L3 hot-path microbenchmarks\n");
    let mut rec = BenchRecorder::default_file();

    // host tensor round trip (the wire format)
    let data: Vec<f32> = (0..64 * 1024).map(|i| i as f32).collect();
    let t = summarize(&bench(3, 20, || {
        let h = HostTensor::from_f32(&[256, 256], &data);
        std::hint::black_box(h.to_f32());
    }));
    println!("host_tensor 256x256 f32 encode+decode: {} ± {}",
             fmt_duration(t.mean), fmt_duration(t.std));
    rec.record_summary("hotpath_host_tensor_roundtrip_s", &t);

    // channel transfer
    let (tx, mut rx) = link();
    let t = summarize(&bench(3, 50, || {
        tx.send(0, HostTensor::from_f32(&[256, 256], &data)).unwrap();
        std::hint::black_box(rx.recv(0).unwrap());
    }));
    println!("tagged channel send+recv 256 KiB:       {} ± {}",
             fmt_duration(t.mean), fmt_duration(t.std));
    rec.record_summary("hotpath_channel_256kib_s", &t);

    // literal upload/download
    if let Ok(_d) = Device::cpu() {
        let h = HostTensor::from_f32(&[256, 256], &data);
        let t = summarize(&bench(3, 20, || {
            let lit = h.to_literal().unwrap();
            std::hint::black_box(HostTensor::from_literal(&lit).unwrap());
        }));
        println!("literal upload+download 256 KiB:        {} ± {}",
                 fmt_duration(t.mean), fmt_duration(t.std));
        rec.record_summary("hotpath_literal_roundtrip_s", &t);
    }

    // zero-grad churn: the old per-OptStep path (fresh 1 MiB alloc per
    // reset) vs the ZeroCache the stage workers now use
    let t_alloc = summarize(&bench(3, 20, || {
        std::hint::black_box(
            HostTensor::zeros(&[512, 512], DType::F32).to_literal().unwrap(),
        );
    }));
    println!("zero-literal alloc 1 MiB (old path):    {} ± {}",
             fmt_duration(t_alloc.mean), fmt_duration(t_alloc.std));
    rec.record_summary("hotpath_zero_alloc_1mib_s", &t_alloc);

    let mut zc = ZeroCache::new();
    let t_cached = summarize(&bench(3, 20, || {
        std::hint::black_box(zc.get(&[512, 512], DType::F32));
    }));
    assert_eq!(zc.len(), 1, "cache must hold one literal per shape");
    println!("zero-literal via ZeroCache (reused):    {} ± {}  ({:.0}x)",
             fmt_duration(t_cached.mean), fmt_duration(t_cached.std),
             t_alloc.mean / t_cached.mean.max(1e-12));
    rec.record_summary("hotpath_zero_cached_s", &t_cached);

    // executable dispatch floor (tiny init artifact, if present)
    if Path::new("artifacts/transformer-tiny/manifest.json").exists() {
        let m = Manifest::load(Path::new("artifacts"), "transformer-tiny")?;
        let d = Device::cpu()?;
        let exe = d.load(&m.stages[0].init.file)?;
        let t = summarize(&bench(2, 10, || {
            std::hint::black_box(exe.run(&[scalar_i32(0)]).unwrap());
        }));
        println!("stage0 init dispatch+run:               {} ± {}",
                 fmt_duration(t.mean), fmt_duration(t.std));
        rec.record_summary("hotpath_init_dispatch_s", &t);
    } else {
        println!("(artifacts missing — skipping dispatch bench)");
    }

    match rec.write() {
        Ok(()) => println!("\nwrote BENCH_sim.json"),
        Err(e) => eprintln!("\nwarning: could not write BENCH_sim.json: {e}"),
    }
    Ok(())
}
