//! Bench: regenerate the paper's Table 1 (bubble ratios & gains,
//! simulated vs closed-form) — `cargo bench --bench table1_bubble_ratios`.
fn main() {
    print!("{}", twobp::experiments::table1());
    println!("(Fig 1 timelines: `twobp gantt` or `cargo bench --bench fig3_throughput`)");
}
