//! Bench: regenerate Fig 4 (max per-rank peak memory, +/-2BP) from real
//! runs with byte-exact stash accounting.
//! `cargo bench --bench fig4_memory [-- --steps N]`

/// Presets: TWOBP_BENCH_PRESETS="a,b" overrides (quick CI runs); default
/// is the paper's four CPU-scale models.
fn presets() -> Vec<String> {
    match std::env::var("TWOBP_BENCH_PRESETS") {
        Ok(s) => s.split(',').map(|x| x.trim().to_string()).collect(),
        Err(_) => twobp::config::BENCH_PRESETS.iter().map(|s| s.to_string())
            .collect(),
    }
}

fn main() {
    let steps = std::env::args().skip_while(|a| a != "--steps").nth(1)
        .and_then(|s| s.parse().ok()).unwrap_or(1);
    match {
        let ps = presets();
        let refs: Vec<&str> = ps.iter().map(|s| s.as_str()).collect();
        twobp::experiments::fig4(steps, &refs)
    } {
        Ok(s) => print!("{s}"),
        Err(e) => { eprintln!("fig4 failed: {e:#}"); std::process::exit(1); }
    }
}
