//! Bench: schedule-space sweep throughput — the event-driven engine +
//! parallel grid runner against the sequential linear-scan baseline.
//!
//! ```text
//! cargo bench --bench sweep_throughput [-- --quick] [-- --threads K]
//!     [-- --baseline BENCH_baseline.json]
//!     [-- --write-baseline BENCH_baseline.json]
//! ```
//!
//! Two parts:
//!
//! 1. **Differential comparison** (≥1k cells, 12–64 ranks — the regime
//!    where the O(total_ops × n_ranks) baseline hurts): times the old
//!    engine sequentially, the event-driven engine sequentially (span
//!    recording and the span-free scoring fast path separately), and
//!    the scoring path across all cores — asserting along the way that
//!    every variant produces bit-identical results per cell.
//!    Acceptance target: ≥5x combined speedup.
//! 2. **Throughput grid** (~10k cells up to 64 ranks × 2048 total
//!    microbatch-ops): scoring fast path (per-worker `Scratch`) +
//!    parallel only, repeated 3× and reported as cells/sec mean ± std.
//!
//! Both parts are appended to `BENCH_sim.json` (see
//! `util::stats::BenchRecorder`) so the perf trajectory is tracked
//! across PRs.
//!
//! **Regression gate** (the CI guard over the perf trajectory): with
//! `--baseline <file>`, the measured cells/sec mean is compared against
//! the committed baseline entry for the current mode
//! (`quick_cells_per_sec` / `full_cells_per_sec`) and the process exits
//! non-zero on a >20% regression.  `--write-baseline <file>` refreshes
//! that entry in place — run it on a quiet machine when a deliberate
//! change moves the number.

use std::path::Path;
use std::time::Instant;

use twobp::experiments::sweep::{self, Cell, CellOut};
use twobp::sim::Scratch;
use twobp::util::args::Args;
use twobp::util::json::{obj, Json};
use twobp::util::stats::{fmt_duration, summarize, BenchRecorder};

fn time<R>(f: impl FnOnce() -> R) -> (R, f64) {
    let t0 = Instant::now();
    let r = f();
    (r, t0.elapsed().as_secs_f64())
}

fn assert_identical(cells: &[Cell], a: &[CellOut], b: &[CellOut],
                    what: &str) {
    assert_eq!(a.len(), b.len(), "{what}: result count");
    for i in 0..cells.len() {
        assert_eq!(
            a[i].makespan.to_bits(), b[i].makespan.to_bits(),
            "{what}: makespan diverged at cell {i} ({})",
            cells[i].describe()
        );
        assert_eq!(
            a[i].bubble_ratio.to_bits(), b[i].bubble_ratio.to_bits(),
            "{what}: bubble ratio diverged at cell {i} ({})",
            cells[i].describe()
        );
    }
}

fn main() {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let args = Args::parse(&argv, &["quick"]);
    let quick = args.has("quick");
    let threads = match args.get_usize("threads", 0) {
        0 => sweep::default_threads(),
        t => t,
    };
    let mut rec = BenchRecorder::default_file();

    let ratios = [(1.0, 1.0, 1.0), (1.0, 1.2, 0.8), (1.0, 0.6, 1.4),
                  (1.0, 1.5, 0.5)];
    let comms = [0.0, 0.05, 0.2];

    // -- part 1: differential comparison against the naive baseline --------
    let cmp_ranks: &[usize] =
        if quick { &[2, 4, 8] } else { &[12, 16, 24, 32, 48, 64] };
    let cmp_mults: &[usize] = if quick { &[1] } else { &[1, 2] };
    let cells = sweep::grid(cmp_ranks, cmp_mults, &ratios, &comms);
    if !quick {
        assert!(cells.len() >= 1000,
                "comparison grid shrank below 1k cells ({})", cells.len());
    }
    let total_ops_est: usize = cells.iter().map(|c| {
        // fwd + p1 (+ fused p2) per microbatch per rank, roughly
        c.n_ranks * c.n_microbatches * if c.two_bp { 2 } else { 3 }
    }).sum();
    println!(
        "sweep_throughput: comparison grid = {} cells (~{} sim ops), \
         {threads} threads available\n",
        cells.len(), total_ops_est
    );

    let (naive, t_naive) =
        time(|| sweep::run_grid(&cells, 1, |_, c| sweep::eval_naive(c)));
    println!("  naive engine, sequential     : {}",
             fmt_duration(t_naive));
    let (ev_seq, t_seq) =
        time(|| sweep::run_grid(&cells, 1, |_, c| sweep::eval(c)));
    println!("  event-driven (spans), seq    : {}  ({:.2}x)",
             fmt_duration(t_seq), t_naive / t_seq);
    let (sc_seq, t_sc_seq) = time(|| {
        sweep::run_grid_with(&cells, 1, Scratch::new,
                             |s, _, c| sweep::eval_scored(c, s))
    });
    println!("  scoring fast path, seq       : {}  ({:.2}x)",
             fmt_duration(t_sc_seq), t_naive / t_sc_seq);
    let (sc_par, t_par) = time(|| {
        sweep::run_grid_with(&cells, threads, Scratch::new,
                             |s, _, c| sweep::eval_scored(c, s))
    });
    println!("  scoring path, {threads:>2} threads     : {}  ({:.2}x)",
             fmt_duration(t_par), t_naive / t_par);

    assert_identical(&cells, &naive, &ev_seq, "naive vs event(seq)");
    assert_identical(&cells, &ev_seq, &sc_seq, "event(seq) vs scored(seq)");
    assert_identical(&cells, &sc_seq, &sc_par, "scored(seq) vs scored(par)");
    println!("  results: all {} cells bit-identical across engines, \
              tiers, and thread counts", cells.len());

    let speedup_engine = t_naive / t_sc_seq;
    let speedup_total = t_naive / t_par;
    println!(
        "\n  speedup: engine alone {speedup_engine:.2}x, engine+parallel \
         {speedup_total:.2}x  (acceptance target >= 5x)\n"
    );

    rec.record("sweep_comparison", obj(vec![
        ("cells", Json::Num(cells.len() as f64)),
        ("naive_seq_s", Json::Num(t_naive)),
        ("event_seq_s", Json::Num(t_seq)),
        ("scored_seq_s", Json::Num(t_sc_seq)),
        ("scored_par_s", Json::Num(t_par)),
        ("speedup_engine", Json::Num(speedup_engine)),
        ("speedup_total", Json::Num(speedup_total)),
        ("threads", Json::Num(threads as f64)),
        ("identical", Json::Bool(true)),
    ]));

    // -- part 2: big-grid throughput (event-driven + parallel only) ---------
    let tp_ranks: &[usize] = if quick {
        &[2, 4, 8]
    } else {
        &[2, 3, 4, 6, 8, 12, 16, 24, 32, 48, 64]
    };
    let tp_mults: &[usize] = if quick { &[1] } else { &[1, 2, 3, 4] };
    let tp_ratios = [(1.0, 1.0, 1.0), (1.0, 1.2, 0.8), (1.0, 0.6, 1.4),
                     (1.0, 1.5, 0.5), (1.0, 0.8, 1.2), (1.0, 2.0, 1.0)];
    let tp_comms = [0.0, 0.02, 0.1, 0.3];
    let big = sweep::grid(tp_ranks, tp_mults, &tp_ratios, &tp_comms);
    println!("throughput grid = {} cells (ranks up to {}):",
             big.len(), tp_ranks.last().unwrap());

    let reps = if quick { 1 } else { 3 };
    let mut cps = Vec::with_capacity(reps);
    let mut sim_ops = 0usize;
    for rep in 0..reps {
        let (outs, dt) = time(|| {
            sweep::run_grid_with(&big, threads, Scratch::new,
                                 |s, _, c| sweep::eval_scored(c, s))
        });
        sim_ops = outs.iter().map(|o| o.total_ops).sum();
        cps.push(big.len() as f64 / dt);
        println!("  rep {rep}: {} -> {:.0} cells/s ({:.2e} plan ops/s)",
                 fmt_duration(dt), big.len() as f64 / dt,
                 sim_ops as f64 / dt);
    }
    let s = summarize(&cps);
    println!("\n  cells/sec: mean {:.0} ± {:.0} (n={})", s.mean, s.std, s.n);

    rec.record("sweep_throughput", obj(vec![
        ("cells", Json::Num(big.len() as f64)),
        ("plan_ops", Json::Num(sim_ops as f64)),
        ("threads", Json::Num(threads as f64)),
        ("quick", Json::Bool(quick)),
    ]));
    rec.record_summary("sweep_throughput_cells_per_sec", &s);
    match rec.write() {
        Ok(()) => println!("  wrote BENCH_sim.json"),
        Err(e) => eprintln!("  warning: could not write BENCH_sim.json: {e}"),
    }

    // -- part 3: cells/sec regression gate vs a committed baseline ----------
    let mode_key = if quick {
        "quick_cells_per_sec"
    } else {
        "full_cells_per_sec"
    };
    if let Some(path) = args.get("write-baseline") {
        let mut base = BenchRecorder::open(Path::new(path));
        base.record(mode_key, Json::Num(s.mean));
        match base.write() {
            Ok(()) => println!("  wrote {mode_key} = {:.0} to {path}", s.mean),
            Err(e) => {
                eprintln!("FAIL: could not write baseline {path}: {e}");
                std::process::exit(1);
            }
        }
    }
    if let Some(path) = args.get("baseline") {
        let base_cps = std::fs::read_to_string(path)
            .ok()
            .and_then(|t| Json::parse(&t).ok())
            .and_then(|v| v.get(mode_key).and_then(|x| x.as_f64()));
        match base_cps {
            None => {
                eprintln!(
                    "FAIL: baseline {path} is missing a numeric \
                     '{mode_key}' entry"
                );
                std::process::exit(1);
            }
            Some(base_cps) => {
                let ratio = s.mean / base_cps;
                println!(
                    "  regression gate: {:.0} cells/s vs baseline {:.0} \
                     ({:.2}x, fail below 0.80x)",
                    s.mean, base_cps, ratio
                );
                if ratio < 0.8 {
                    eprintln!(
                        "FAIL: sweep throughput regressed >20% vs {path} \
                         ({:.0} < 0.8 x {:.0} cells/s)",
                        s.mean, base_cps
                    );
                    std::process::exit(1);
                }
            }
        }
    }

    if !quick && speedup_total < 5.0 {
        if threads > 1 {
            eprintln!(
                "FAIL: combined speedup {speedup_total:.2}x below the 5x \
                 acceptance target"
            );
            std::process::exit(1);
        }
        eprintln!(
            "warning: single-threaded host — combined speedup \
             {speedup_total:.2}x is engine-only (target assumes the \
             parallel runner has cores to use)"
        );
    }
}
