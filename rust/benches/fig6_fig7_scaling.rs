//! Bench: regenerate Figs 6/7 (fixed- and variable-size scaling to
//! N=4/8/16 with inter-node comm penalty and OOM detection), calibrated
//! from real per-op costs of a BERT-like run.
//! `cargo bench --bench fig6_fig7_scaling [-- --steps N]`
fn main() {
    let steps = std::env::args().skip_while(|a| a != "--steps").nth(1)
        .and_then(|s| s.parse().ok()).unwrap_or(2);
    match twobp::experiments::fig6_fig7(
        steps,
        &std::env::var("TWOBP_BENCH_PRESET")
            .unwrap_or_else(|_| "bert-scale-fixed".into()),
    ) {
        Ok(s) => print!("{s}"),
        Err(e) => { eprintln!("fig6/7 failed: {e:#}"); std::process::exit(1); }
    }
}
