//! Bench: regenerate Table 3 (concat vs loop backward-p2 under 1F1B-1 +
//! 2BP) — the paper found the two within noise of each other.
//! `cargo bench --bench table3_concat [-- --steps N]`

/// Presets: TWOBP_BENCH_PRESETS="a,b" overrides (quick CI runs); default
/// is the paper's four CPU-scale models.
fn presets() -> Vec<String> {
    match std::env::var("TWOBP_BENCH_PRESETS") {
        Ok(s) => s.split(',').map(|x| x.trim().to_string()).collect(),
        Err(_) => twobp::config::BENCH_PRESETS.iter().map(|s| s.to_string())
            .collect(),
    }
}

fn main() {
    let steps = std::env::args().skip_while(|a| a != "--steps").nth(1)
        .and_then(|s| s.parse().ok()).unwrap_or(2);
    match {
        let ps = presets();
        let refs: Vec<&str> = ps.iter().map(|s| s.as_str()).collect();
        twobp::experiments::table3(steps, &refs)
    } {
        Ok(s) => print!("{s}"),
        Err(e) => { eprintln!("table3 failed: {e:#}"); std::process::exit(1); }
    }
}
