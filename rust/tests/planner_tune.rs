//! Acceptance tests for the `planner/` subsystem (ISSUE 2): on a 4-rank
//! LLaMa-like profile with a *binding* memory budget, `tune` must find
//! a valid plan whose throughput is at least that of the best built-in
//! schedule that fits, deterministically for a fixed seed; and every
//! emitted plan must pass `schedule::validate` and round-trip through
//! the plan DSL.

use twobp::experiments::sweep::combos;
use twobp::planner::{tune, BeamConfig, TuneProfile};
use twobp::schedule::{generate, plan_io, validate::validate};
use twobp::sim::{eval_plan, CostModel, MemModel};

const SEED: u64 = 0x2B92_0240;

fn cfg_with(budget: Option<u64>) -> BeamConfig {
    BeamConfig { budget_bytes: budget, seed: SEED, ..BeamConfig::default() }
}

/// A budget that binds by construction: one byte below the peak of the
/// *unconstrained* tuning winner, so the throughput champion itself no
/// longer fits and the search must trade memory for speed.
fn binding_budget(profile: &TuneProfile, n: usize) -> u64 {
    let unconstrained = tune(profile, n, &cfg_with(None)).unwrap();
    unconstrained.best.max_peak - 1
}

/// Best built-in (generator) schedule that fits `budget`, recomputed
/// independently of the tuner's bookkeeping over all combos × the
/// tuner's microbatch grid.  Returns (throughput, description).
fn best_named_fitting(
    profile: &TuneProfile,
    n: usize,
    budget: Option<u64>,
) -> Option<(f64, String)> {
    let mut best: Option<(f64, String)> = None;
    for (kind, two_bp) in combos() {
        for m in [n, 3 * n / 2, 2 * n, 3 * n, 4 * n] {
            let plan = generate(kind, two_bp, n, m, false);
            let ev = eval_plan(&plan, &profile.costs, Some(&profile.mem),
                               budget)
                .unwrap();
            if !ev.fits {
                continue;
            }
            let tput =
                ev.result.throughput(profile.samples_per_microbatch, m);
            if best.as_ref().map(|(t, _)| tput > *t).unwrap_or(true) {
                best = Some((tput, plan.describe()));
            }
        }
    }
    best
}

#[test]
fn tune_beats_named_schedules_under_binding_budget() {
    let n = 4;
    let profile = TuneProfile::llama_like(n);
    let budget = binding_budget(&profile, n);
    let report = tune(&profile, n, &cfg_with(Some(budget))).unwrap();

    // the budget really binds: some candidates were rejected for memory
    assert!(report.rejected_budget > 0, "budget was not binding");

    // 1. the winner is a valid plan and fits the budget
    validate(&report.best.plan).unwrap();
    assert!(
        report.best.max_peak <= budget,
        "winner peak {} over budget {budget}",
        report.best.max_peak
    );

    // 2. winner throughput >= every built-in schedule that fits
    let (named_tput, named_desc) =
        best_named_fitting(&profile, n, Some(budget))
            .expect("no built-in schedule fits the budget");
    assert!(
        report.best.throughput >= named_tput - 1e-12,
        "planner winner {:.6} samples/s below best built-in {named_desc} \
         at {named_tput:.6}",
        report.best.throughput
    );

    // 3. the tuner's own named-best agrees with the independent scan
    let nb = report.named_best.as_ref().expect("tuner lost the named best");
    assert!(
        (nb.throughput - named_tput).abs() <= 1e-9 * named_tput.max(1.0),
        "tuner named-best {:.6} != independent scan {named_tput:.6}",
        nb.throughput
    );

    // 4. the winner's claimed numbers replay exactly in the simulator
    let replay = eval_plan(
        &report.best.plan,
        &profile.costs,
        Some(&profile.mem),
        Some(budget),
    )
    .unwrap();
    assert_eq!(
        replay.result.makespan.to_bits(),
        report.best.makespan.to_bits()
    );
    assert_eq!(replay.max_peak, report.best.max_peak);

    // 5. the winner round-trips through the plan DSL bit-identically
    let back = plan_io::parse(&report.best.text).unwrap();
    assert_eq!(back, report.best.plan);
    validate(&back).unwrap();
}

/// ISSUE 10 acceptance: on a skewed per-layer model under a *binding*
/// memory budget, the joint partition × schedule co-search must beat
/// the best fixed-partition winner — searching the layer cuts buys
/// real simulated step time, not just provenance.  The fixed baseline
/// is the balanced contiguous split at dp=1 (exactly what the
/// pre-partition planner would tune), under the same beam config and
/// budget.
#[test]
fn co_search_beats_fixed_partition_under_binding_budget() {
    use twobp::metrics::observer::NullObserver;
    use twobp::planner::{co_search, CoSearchConfig, ModelProfile};
    use twobp::schedule::Partition;

    let devices = 2;
    let layers = 8;
    let mut model =
        ModelProfile::from_profile(&TuneProfile::llama_like(layers));
    model.allreduce_per_byte = 2e-11;
    // layer 0 is ×6 hot: the balanced split leaves stage 0 with the hot
    // layer plus three peers, so the cuts themselves are load-bearing
    model.layers[0].fwd *= 6.0;
    model.layers[0].p1 *= 6.0;
    model.layers[0].p2 *= 6.0;

    let balanced = Partition::balanced(layers, devices, 1);
    let rolled = model.roll_up(&balanced).unwrap();
    let budget = binding_budget(&rolled, devices);
    let baseline = tune(&rolled, devices, &cfg_with(Some(budget))).unwrap();
    assert!(baseline.rejected_budget > 0, "budget was not binding");
    // dp=1: no allreduce term, step time is the plan makespan
    let baseline_step = baseline.best.makespan;

    let cfg = CoSearchConfig::new(devices, cfg_with(Some(budget)));
    let rep = co_search(&model, &cfg, &mut NullObserver).unwrap();

    // the pp=devices cell starts from the balanced baseline and must
    // migrate its boundary off the hot layer, strictly beating the
    // fixed split's winner
    let pp2 = rep
        .cells
        .iter()
        .find(|c| c.pp == devices)
        .expect("full-depth pipeline cell missing");
    assert!(pp2.migrations > 0, "no boundary ever migrated");
    assert_ne!(pp2.partition.cuts, balanced.cuts, "cuts did not move");
    assert!(
        pp2.step_time < baseline_step - 1e-12,
        "co-search step time {:.6} not better than the fixed-partition \
         winner's {baseline_step:.6}",
        pp2.step_time,
    );

    // winner integrity: valid, fits, and carries its partition through
    // the v2 plan DSL
    let best = rep.best();
    validate(&best.candidate.plan).unwrap();
    assert!(best.max_peak <= budget, "winner over budget");
    let back = plan_io::parse(&best.candidate.text).unwrap();
    assert_eq!(back.partition.as_ref(), Some(&best.partition));
    // and the report really ranked it best
    for c in &rep.cells {
        assert!(best.throughput >= c.throughput - 1e-12);
    }
}

#[test]
fn tune_is_reproducible_for_a_fixed_seed() {
    let n = 4;
    let profile = TuneProfile::llama_like(n);
    let budget = binding_budget(&profile, n);
    let run = |threads: usize| {
        let cfg = BeamConfig {
            threads,
            ..cfg_with(Some(budget))
        };
        tune(&profile, n, &cfg).unwrap()
    };
    let a = run(1);
    let b = run(4);
    assert_eq!(a.best.text, b.best.text, "thread count changed the winner");
    assert_eq!(a.best.makespan.to_bits(), b.best.makespan.to_bits());
    assert_eq!(a.evaluated, b.evaluated);
    assert_eq!(a.history.len(), b.history.len());
    for (x, y) in a.history.iter().zip(&b.history) {
        assert_eq!(x.to_bits(), y.to_bits());
    }
}

/// The measured-profile path (ISSUE 5): a profile built by
/// `TuneProfile::from_measured` from "measured-like" absolute-seconds
/// costs (millisecond scale, per-stage skew like the skewed synthetic
/// preset — far from the ratio profiles' ~1.0 units) must tune exactly
/// like any other profile: valid winner, >= every named schedule under
/// the same model, bit-identical Tier B replay.  Pairs with the
/// `cost_model_from_flops` normalization fix: nothing downstream may
/// assume costs live near 1.0.
#[test]
fn measured_profile_tune_beats_named_at_absolute_seconds_scale() {
    let n = 4;
    let scale = [1.0, 4.0, 2.0, 3.0];
    let ms = 1e-3;
    let mut costs = CostModel::unit(n);
    costs.fwd = scale.iter().map(|s| 1.20 * s * ms).collect();
    costs.p1 = scale.iter().map(|s| 1.32 * s * ms).collect();
    costs.p2 = scale.iter().map(|s| 1.08 * s * ms).collect();
    costs.opt = vec![0.06 * ms; n];
    costs.loss = 0.084 * ms;
    let mem = MemModel {
        static_bytes: vec![4352; n],
        res1: vec![512; n],
        res2: vec![544; n],
        inter: vec![512; n],
    };
    let profile =
        TuneProfile::from_measured("measured-like", costs, mem, 2).unwrap();
    let report = tune(&profile, n, &cfg_with(None)).unwrap();
    validate(&report.best.plan).unwrap();
    let (named_tput, named_desc) =
        best_named_fitting(&profile, n, None).unwrap();
    assert!(
        report.best.throughput >= named_tput - 1e-12,
        "measured-profile winner {:.6} below named {named_desc} at \
         {named_tput:.6}",
        report.best.throughput
    );
    // the winner's claimed numbers replay bit-identically through the
    // Tier B path at this absolute scale too
    let replay = eval_plan(
        &report.best.plan,
        &profile.costs,
        Some(&profile.mem),
        None,
    )
    .unwrap();
    assert_eq!(
        replay.result.makespan.to_bits(),
        report.best.makespan.to_bits()
    );
    // and round-trips through the DSL
    let back = plan_io::parse(&report.best.text).unwrap();
    assert_eq!(back, report.best.plan);
}

#[test]
fn unconstrained_tune_is_at_least_as_good_as_every_named_schedule() {
    let n = 4;
    let profile = TuneProfile::llama_like(n);
    let report = tune(&profile, n, &cfg_with(None)).unwrap();
    validate(&report.best.plan).unwrap();
    let (named_tput, named_desc) =
        best_named_fitting(&profile, n, None).unwrap();
    assert!(
        report.best.throughput >= named_tput - 1e-12,
        "unconstrained winner {:.6} below named {named_desc} \
         at {named_tput:.6}",
        report.best.throughput
    );
    if let Some(gain) = report.gain_vs_named() {
        assert!(gain >= 1.0 - 1e-12, "gain vs named {gain} < 1");
    }
    // winners export as parseable, valid .plan text
    let back = plan_io::parse(&report.best.text).unwrap();
    validate(&back).unwrap();
    assert_eq!(back, report.best.plan);
}
