//! Tests over the experiment harness's simulator-only paths (no
//! artifacts needed — these always run).

use twobp::experiments::{self, sweep};
use twobp::schedule::{generate, validate::validate, ScheduleKind};
use twobp::sim::{simulate, simulate_naive, CostModel, MemModel};

#[test]
fn table1_report_contains_all_schedules_and_matches() {
    let out = experiments::table1();
    for name in ["naive", "gpipe", "1f1b-1", "1f1b-2"] {
        assert!(out.contains(name), "missing {name}");
    }
    // sim and formula columns must agree: every row renders both with
    // identical text for bubble ratios (4 decimal places)
    for line in out.lines().filter(|l| l.starts_with("| ")) {
        let cells: Vec<&str> =
            line.split('|').map(|c| c.trim()).filter(|c| !c.is_empty())
                .collect();
        if cells.len() == 8 && cells[1].parse::<usize>().is_ok() {
            assert_eq!(cells[2], cells[3], "bubble mismatch: {line}");
            assert_eq!(cells[4], cells[5], "2BP bubble mismatch: {line}");
        }
    }
}

#[test]
fn fig1_renders_all_eight_timelines() {
    let out = experiments::fig1(4, 64);
    assert_eq!(out.matches("makespan =").count(), 8);
    assert_eq!(out.matches("+2bp").count(), 4);
    // 2BP timelines must contain deferred p2 spans
    assert!(out.contains('2'));
}

#[test]
fn gain_monotone_in_p2_share() {
    // the larger backward-p2's share of the backward pass, the more 2BP
    // can defer into bubbles: gain must be non-decreasing in p2 share
    // (1F1B-1, fixed total backward cost)
    let n = 4;
    let mut last = 0.0;
    for p2_share in [0.2, 0.4, 0.6, 0.8] {
        let cm = CostModel::ratios(n, 1.0, 2.0 * (1.0 - p2_share),
                                   2.0 * p2_share);
        let a = simulate(&generate(ScheduleKind::OneF1B1, false, n, 0, false),
                         &cm, None).unwrap();
        let b = simulate(&generate(ScheduleKind::OneF1B1, true, n, 0, false),
                         &cm, None).unwrap();
        let gain = a.makespan / b.makespan;
        assert!(gain >= last - 1e-9,
                "gain not monotone at share {p2_share}: {gain} < {last}");
        assert!(gain >= 1.0 - 1e-9);
        last = gain;
    }
    assert!(last > 1.2, "gain never became substantial: {last}");
}

#[test]
fn comm_degrades_gain_like_paper_fig6() {
    // paper §4.3: observed gain decays with communication share
    let n = 8;
    let gain_at = |comm: f64| {
        let mut cm = CostModel::unit(n);
        cm.comm = comm;
        let a = simulate(&generate(ScheduleKind::OneF1B1, false, n, 0, false),
                         &cm, None).unwrap();
        let b = simulate(&generate(ScheduleKind::OneF1B1, true, n, 0, false),
                         &cm, None).unwrap();
        a.makespan / b.makespan
    };
    assert!(gain_at(0.5) < gain_at(0.0));
}

#[test]
fn checkpointing_ablation_tradeoff_shape() {
    // pure-sim version of the §5 ablation: dropping inter from the stash
    // must reduce peak memory; surcharging p2 must not increase
    // throughput
    let n = 4;
    let plan = generate(ScheduleKind::OneF1B2, true, n, 0, false);
    validate(&plan).unwrap();
    let mm = MemModel {
        static_bytes: vec![100; n],
        res1: vec![10; n],
        res2: vec![50; n],
        inter: vec![40; n],
    };
    let base = simulate(&plan, &CostModel::unit(n), Some(&mm)).unwrap();
    let mm_ckpt = MemModel { inter: vec![0; n], ..mm };
    let mut cm = CostModel::unit(n);
    for r in 0..n {
        cm.p2[r] += 0.5 * cm.p1[r];
    }
    let ckpt = simulate(&plan, &cm, Some(&mm_ckpt)).unwrap();
    assert!(ckpt.max_peak() < base.max_peak());
    assert!(ckpt.makespan >= base.makespan - 1e-9);
}

#[test]
fn schedule_space_sweep_reports_all_variants() {
    let out = experiments::schedule_space(&[2, 4], &[1], 0);
    for name in ["naive", "gpipe", "1f1b-1", "1f1b-2", "1f1b-2-eager+2bp"] {
        assert!(out.contains(name), "missing {name} in:\n{out}");
    }
    assert!(out.contains("cells/s"), "missing throughput footer");
    // 9 variant combos × 2 ranks × 1 mult × 3 ratios × 2 comms
    assert!(out.contains("108 cells"), "unexpected cell count:\n{out}");
}

#[test]
fn sweep_results_identical_across_engines_and_thread_counts() {
    let cells = sweep::grid(&[2, 4, 6], &[1, 2],
                            &[(1.0, 1.0, 1.0), (1.0, 1.3, 0.7)], &[0.0, 0.15]);
    let event_par = sweep::run_grid(&cells, 8, |_, c| sweep::eval(c));
    let event_seq = sweep::run_grid(&cells, 1, |_, c| sweep::eval(c));
    let naive_seq = sweep::run_grid(&cells, 1, |_, c| sweep::eval_naive(c));
    for i in 0..cells.len() {
        for other in [&event_seq[i], &naive_seq[i]] {
            assert_eq!(event_par[i].makespan.to_bits(),
                       other.makespan.to_bits(),
                       "cell {i}: {}", cells[i].describe());
            assert_eq!(event_par[i].bubble_ratio.to_bits(),
                       other.bubble_ratio.to_bits(),
                       "cell {i}: {}", cells[i].describe());
        }
    }
}

#[test]
fn bubble_ratio_closed_form_holds_at_scale() {
    // the event engine must stay exact far beyond the unit-test N range
    // (this is the regime the old linear scan made too slow to sweep)
    for n in [32usize, 64] {
        let nf = n as f64;
        let plan = generate(ScheduleKind::OneF1B1, true, n, 0, false);
        let res = simulate(&plan, &CostModel::unit(n), None).unwrap();
        let want = (nf - 1.0) / (nf - 1.0 + 3.0 * nf);
        assert!((res.bubble_ratio - want).abs() < 1e-9,
                "N={n}: {} vs {want}", res.bubble_ratio);
    }
}

#[test]
fn naive_reference_engine_agrees_on_experiment_scale_cell() {
    let plan = generate(ScheduleKind::OneF1B2, true, 8, 0, false);
    let mut cm = CostModel::ratios(8, 1.0, 1.4, 0.9);
    cm.comm = 0.05;
    let a = simulate(&plan, &cm, None).unwrap();
    let b = simulate_naive(&plan, &cm, None).unwrap();
    assert_eq!(a.makespan.to_bits(), b.makespan.to_bits());
    assert_eq!(a.bubble_ratio.to_bits(), b.bubble_ratio.to_bits());
    assert_eq!(a.peak_bytes, b.peak_bytes);
}

#[test]
fn memory_planner_style_prediction_consistency() {
    // sim peak with a MemModel must be at least static and at most
    // static + M * (res1+res2+inter) per rank
    let n = 4;
    for kind in ScheduleKind::all() {
        for two_bp in [false, true] {
            let plan = generate(kind, two_bp, n, 0, false);
            let m = plan.n_microbatches as u64;
            let mm = MemModel {
                static_bytes: vec![1000; n],
                res1: vec![7; n],
                res2: vec![13; n],
                inter: vec![5; n],
            };
            let res = simulate(&plan, &CostModel::unit(n), Some(&mm)).unwrap();
            for &p in &res.peak_bytes {
                assert!(p >= 1000);
                assert!(p <= 1000 + m * (7 + 13 + 5),
                        "{} 2bp={two_bp}: peak {p}", kind.name());
            }
        }
    }
}

#[test]
fn plan_space_sweeps_a_directory_of_plan_files() {
    use twobp::schedule::plan_io;

    let dir = std::env::temp_dir().join(format!(
        "twobp_plan_space_{}",
        std::process::id()
    ));
    let _ = std::fs::remove_dir_all(&dir);
    std::fs::create_dir_all(&dir).unwrap();

    // two generator plans of *different* rank counts plus a non-plan
    // file that must be ignored
    let a = generate(ScheduleKind::OneF1B1, true, 2, 4, false);
    let b = generate(ScheduleKind::GPipe, false, 3, 3, false);
    std::fs::write(dir.join("a.plan"), plan_io::to_text(&a)).unwrap();
    std::fs::write(dir.join("b.plan"), plan_io::to_text(&b)).unwrap();
    std::fs::write(dir.join("notes.txt"), "not a plan").unwrap();

    let out = experiments::plan_space(&dir, (1.0, 1.0, 1.0), 0.0, 2).unwrap();
    assert!(out.contains("a.plan") && out.contains("b.plan"), "{out}");
    assert!(!out.contains("notes.txt"), "{out}");
    assert!(out.contains("2 plans"), "{out}");

    // the reported makespan must match a direct Tier B simulation
    let direct = simulate(&a, &CostModel::unit(2), None).unwrap();
    assert!(out.contains(&format!("{:.4}", direct.makespan)), "{out}");

    // invalid plan file fails loudly, naming the file
    std::fs::write(dir.join("bad.plan"), "plan v1\nkind naive\n").unwrap();
    let err = experiments::plan_space(&dir, (1.0, 1.0, 1.0), 0.0, 1)
        .unwrap_err()
        .to_string();
    assert!(err.contains("bad.plan"), "{err}");

    // empty dir errors with guidance
    let empty = dir.join("empty");
    std::fs::create_dir_all(&empty).unwrap();
    let err = experiments::plan_space(&empty, (1.0, 1.0, 1.0), 0.0, 1)
        .unwrap_err()
        .to_string();
    assert!(err.contains("no .plan files"), "{err}");

    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn planner_search_report_covers_the_budget_ladder() {
    let out = experiments::planner_search(2, 0, 0x2B9);
    assert!(out.contains("Planner search"), "missing title:\n{out}");
    // the unconstrained row plus four derived budget rows
    assert!(out.contains("∞"), "missing unconstrained row:\n{out}");
    assert!(out.contains("planner winner"), "missing winner column:\n{out}");
    assert!(out.contains("search effort per budget"), "missing footer:\n{out}");
}
