//! End-to-end tests of the vendored PJRT stub backend
//! (`vendor/xla-stub`) driving the real pipeline executor on synthetic
//! manifests generated in-process — no Python AOT step, no network.
//!
//! The stub's semantics (deterministic seeded outputs; *integer-valued*
//! gradient deltas so accumulation is exact and order-independent) give
//! these tests real teeth:
//!
//! * parameters after training are **bit-identical** across every
//!   schedule, ±2BP, and loop-vs-concat p2 — the paper's
//!   semantics-preservation claim, checked exactly;
//! * every run's executed op order and byte-exact memory accounting
//!   are verified against the simulator
//!   (`pipeline::verify_report_against_sim`).
#![cfg(feature = "pjrt")]

use std::path::{Path, PathBuf};

use twobp::config::{P2Mode, RunConfig};
use twobp::models::synthetic::{write_artifacts, SyntheticSpec};
use twobp::models::Manifest;
use twobp::pipeline::{train, verify_report_against_sim, Cluster};
use twobp::schedule::ScheduleKind;

/// Per-test artifact dir (tests run concurrently in one process).
fn setup(tag: &str) -> (PathBuf, Manifest) {
    let dir = std::env::temp_dir()
        .join(format!("twobp-stub-test-{tag}-{}", std::process::id()));
    let manifest = write_artifacts(&dir, &SyntheticSpec::tiny())
        .expect("write synthetic artifacts");
    (dir, manifest)
}

fn cfg(
    dir: &Path,
    kind: ScheduleKind,
    two_bp: bool,
    steps: usize,
    m: usize,
) -> RunConfig {
    RunConfig {
        preset: "synthetic".into(),
        artifacts: dir.to_path_buf(),
        schedule: kind,
        two_bp,
        steps,
        n_microbatches: m,
        ..RunConfig::default()
    }
}

#[test]
fn stub_runs_every_schedule_end_to_end() {
    let (dir, manifest) = setup("smoke");
    for kind in [ScheduleKind::GPipe, ScheduleKind::OneF1B1,
                 ScheduleKind::OneF1B2] {
        for two_bp in [false, true] {
            let c = cfg(&dir, kind, two_bp, 2, 0);
            let report = train(&c)
                .unwrap_or_else(|e| panic!("{} 2bp={two_bp}: {e:#}",
                                           kind.name()));
            assert_eq!(report.losses.len(), 2, "{} 2bp={two_bp}",
                       kind.name());
            assert!(report.losses.iter().all(|l| l.is_finite()));
            assert!(report.max_peak() > 0);
            verify_report_against_sim(&report, &manifest, 2)
                .unwrap_or_else(|e| panic!("{} 2bp={two_bp}: {e:#}",
                                           kind.name()));
        }
    }
    let _ = std::fs::remove_dir_all(&dir);
}

/// Non-greedy plans execute in exactly the order the simulator
/// dispatches, so the accountant's model peak must equal the
/// simulator's peak bytes per rank — byte for byte.
#[test]
fn fused_op_order_and_peak_match_sim_exactly() {
    let (dir, manifest) = setup("order");
    for kind in [ScheduleKind::GPipe, ScheduleKind::OneF1B1] {
        let report = train(&cfg(&dir, kind, false, 1, 0)).expect("train");
        let costs = manifest.cost_model_from_flops(0.0);
        let mm = manifest.mem_model();
        let sim = twobp::sim::simulate(&report.plan, &costs, Some(&mm))
            .expect("sim");
        assert_eq!(report.peak_model_bytes(), sim.peak_bytes,
                   "{}", kind.name());
        verify_report_against_sim(&report, &manifest, 1)
            .unwrap_or_else(|e| panic!("{}: {e:#}", kind.name()));
    }
    let _ = std::fs::remove_dir_all(&dir);
}

/// The paper's core claim, checked *exactly* under the stub: the same
/// data + seed yields bit-identical parameters whether backward is
/// fused or split/reordered, for every schedule (integer gradient
/// deltas make f32 accumulation exact, hence order-independent).
#[test]
fn param_updates_identical_across_schedules_and_2bp() {
    let (dir, _) = setup("equiv");
    // fixed M = 4 for every schedule: equivalence needs identical data
    // and effective batch size (1F1B-2's default M = 2N differs)
    let m = 4;
    let baseline = train(&cfg(&dir, ScheduleKind::GPipe, false, 2, m))
        .expect("baseline");
    let base_ck = baseline.param_checksum();
    let base_digests = baseline.param_digests();
    for kind in [ScheduleKind::Naive, ScheduleKind::GPipe,
                 ScheduleKind::OneF1B1, ScheduleKind::OneF1B2] {
        for two_bp in [false, true] {
            let r = train(&cfg(&dir, kind, two_bp, 2, m)).expect("train");
            assert_eq!(
                r.param_digests(), base_digests,
                "{} 2bp={two_bp}: param bytes diverged from the fused \
                 baseline",
                kind.name()
            );
            assert_eq!(
                r.param_checksum(), base_ck,
                "{} 2bp={two_bp}: params diverged from the fused baseline",
                kind.name()
            );
            // per-step mean losses: same per-mb values, possibly summed
            // in a different microbatch order -> tolerance, not bits
            assert_eq!(r.losses.len(), baseline.losses.len());
            for (a, b) in r.losses.iter().zip(baseline.losses.iter()) {
                assert!(
                    (a - b).abs() < 1e-5,
                    "{} 2bp={two_bp}: loss {a} vs baseline {b}",
                    kind.name()
                );
            }
        }
    }
    let _ = std::fs::remove_dir_all(&dir);
}

/// Concat-p2 (Fig 2) equals the loop form bit for bit: the stub's
/// `group` mode replays the same per-microbatch delta streams as its
/// `acc` mode (same per-stage seed), mirroring real concatenation.
#[test]
fn concat_p2_equals_loop_p2_bit_for_bit() {
    let (dir, _) = setup("concat");
    let m = SyntheticSpec::tiny().concat_m; // concat engages at exactly M
    let mut loop_cfg = cfg(&dir, ScheduleKind::GPipe, true, 2, m);
    loop_cfg.p2_mode = P2Mode::Loop;
    let mut concat_cfg = loop_cfg.clone();
    concat_cfg.p2_mode = P2Mode::Concat;
    let a = train(&loop_cfg).expect("loop");
    let b = train(&concat_cfg).expect("concat");
    assert_eq!(a.param_digests(), b.param_digests());
    assert_eq!(a.param_checksum(), b.param_checksum());
    assert_eq!(a.losses, b.losses);
    // Prove the concat path actually executed (greedy fills can make
    // middle ranks fall back to loop mode, but under GPipe the last
    // rank never waits in backward — its p1 inputs are local — so its
    // trailing flush always sees all M fresh pending p2s and concats):
    // one BwdP2 span per step there, vs M per step in loop mode.
    let p2_spans = |r: &twobp::pipeline::RunReport| -> usize {
        r.reports
            .iter()
            .find(|w| w.rank == r.plan.n_ranks - 1)
            .expect("last rank report")
            .timings
            .iter()
            .filter(|t| t.kind == twobp::util::gantt::SpanKind::BwdP2)
            .count()
    };
    assert_eq!(p2_spans(&a), m * 2, "loop mode: one span per microbatch");
    assert_eq!(p2_spans(&b), 2, "concat mode: one span per step");
    let _ = std::fs::remove_dir_all(&dir);
}

/// Reruns are deterministic even under greedy p2: fill *order* may
/// differ between runs, but order-independent accumulation makes the
/// result identical.
#[test]
fn greedy_2bp_reruns_are_deterministic() {
    let (dir, _) = setup("det");
    let c = cfg(&dir, ScheduleKind::OneF1B1, true, 3, 0);
    let a = train(&c).expect("first run");
    let b = train(&c).expect("second run");
    assert_eq!(a.losses, b.losses);
    assert_eq!(a.param_digests(), b.param_digests());
    assert_eq!(a.param_checksum(), b.param_checksum());
    let _ = std::fs::remove_dir_all(&dir);
}

/// The calibration round trip (ISSUE 5 acceptance): on the
/// deliberately depth-imbalanced synthetic preset
/// (`SyntheticSpec::skewed`, per-stage stub `cost` busy-delays
/// proportional to the declared flops), measured per-op costs must
/// recover the manifest's flops *shape* from wall time; tuning against
/// the measured profile must beat (or match) every named generator
/// schedule under that model; and the winning plan must execute back
/// on the cluster, verified against the simulator, with executed
/// makespan in the same ballpark as predicted.
#[test]
fn calibration_round_trip_recovers_skew_and_closes_the_loop() {
    use twobp::experiments::sweep::combos;
    use twobp::experiments::tune_and_execute;
    use twobp::planner::beam::microbatch_grid;
    use twobp::planner::{BeamConfig, TuneProfile};
    use twobp::schedule::generate;
    use twobp::sim::eval_plan;

    let dir = std::env::temp_dir()
        .join(format!("twobp-stub-test-calib-{}", std::process::id()));
    let spec = SyntheticSpec::skewed();
    let manifest = write_artifacts(&dir, &spec).expect("write skewed");
    let n = manifest.n_stages;
    let base = RunConfig {
        preset: spec.preset.clone(),
        artifacts: dir.clone(),
        steps: 2,
        n_microbatches: n,
        ..RunConfig::default()
    };
    let cluster = Cluster::new(&base).expect("cluster");
    let (costs, calib) = cluster.calibrate(&base).expect("calibrate");
    assert_eq!(calib.plan.n_ranks, n);
    assert!(!calib.plan.two_bp, "calibration runs the fused baseline");

    // 1. measured costs within tolerance of the flops model's shape
    //    (both mean-normalized per kind; the stub busy-delays are
    //    proportional to the flops, so wall time carries the skew)
    let flops = manifest.cost_model_from_flops(0.0);
    let norm = |xs: &[f64]| -> Vec<f64> {
        let mean = xs.iter().sum::<f64>() / xs.len() as f64;
        xs.iter().map(|x| x / mean).collect()
    };
    for (which, meas, model) in [
        ("fwd", &costs.fwd, &flops.fwd),
        ("p1", &costs.p1, &flops.p1),
        ("p2", &costs.p2, &flops.p2),
    ] {
        for (r, (m, f)) in
            norm(meas).iter().zip(norm(model).iter()).enumerate()
        {
            let rel = (m - f).abs() / f;
            assert!(
                rel < 0.40,
                "{which} stage {r}: measured {m:.3} vs flops {f:.3} \
                 (rel {rel:.2}) — calibration lost the skew"
            );
        }
    }
    // the 4x-flops stage really measures dearest, the 1x cheapest
    assert!(costs.fwd[1] > costs.fwd[3]);
    assert!(costs.fwd[3] > costs.fwd[2]);
    assert!(costs.fwd[2] > costs.fwd[0]);
    // loss is timed separately on the last rank, never folded into p1
    assert!(costs.loss > 0.0, "loss span not attributed");

    // 2. tune against the measured profile; the winner must be >= every
    //    named generator schedule under that model (independent scan)
    let profile = TuneProfile::from_measured(
        "measured:skewed",
        costs.clone(),
        manifest.mem_model(),
        manifest.samples_per_microbatch,
    )
    .expect("profile shapes agree");
    let cfg = BeamConfig {
        beam_width: 6,
        generations: 4,
        mutations_per_parent: 4,
        seed: 0x2B92_0245,
        ..BeamConfig::default()
    };
    let ct = tune_and_execute(
        &cluster, &manifest, &profile, &cfg, &base,
        &mut twobp::metrics::observer::NullObserver,
    )
    .expect("tune + winner execution");
    let mut named_best = 0.0f64;
    for (kind, two_bp) in combos() {
        for &m in &microbatch_grid(n, 4 * n) {
            let plan = generate(kind, two_bp, n, m, false);
            let ev = eval_plan(&plan, &profile.costs, Some(&profile.mem),
                               None)
                .expect("named plans simulate");
            let tput = ev
                .result
                .throughput(profile.samples_per_microbatch, m);
            named_best = named_best.max(tput);
        }
    }
    assert!(
        ct.report.best.throughput >= named_best - 1e-12,
        "winner {:.4} below best named {named_best:.4} under the \
         measured model",
        ct.report.best.throughput
    );
    assert!(ct.report.named_best.is_some());

    // 3. predicted-vs-executed: the stub's sleep-backed costs make the
    //    executed wall makespan physically meaningful; allow a loose
    //    band for scheduler noise and cross-step overlap
    let ratio = ct.executed_makespan / ct.predicted_makespan;
    assert!(
        ratio > 0.4 && ratio < 2.5,
        "executed {:.4}s vs predicted {:.4}s (ratio {ratio:.2})",
        ct.executed_makespan,
        ct.predicted_makespan
    );
    let _ = std::fs::remove_dir_all(&dir);
}

/// Loss spans land only on the last rank (one per microbatch per step),
/// and the measured p1 mean no longer absorbs them: on the tiny
/// cost-free spec the loss executable still takes nonzero wall time, so
/// `measured_costs().loss > 0` while every rank's p1 mean stays the
/// mean of pure p1 spans.
#[test]
fn loss_spans_are_attributed_separately() {
    let (dir, _) = setup("loss-span");
    let m = 4;
    let steps = 2;
    let report = train(&cfg(&dir, ScheduleKind::GPipe, true, steps, m))
        .expect("train");
    let n = report.plan.n_ranks;
    for w in &report.reports {
        let losses = w
            .timings
            .iter()
            .filter(|t| t.kind == twobp::util::gantt::SpanKind::Loss)
            .count();
        let want = if w.rank == n - 1 { m * steps } else { 0 };
        assert_eq!(losses, want, "rank {}", w.rank);
        // loss spans never overlap the rank's p1 spans
        for l in w
            .timings
            .iter()
            .filter(|t| t.kind == twobp::util::gantt::SpanKind::Loss)
        {
            for p in w.timings.iter().filter(|t| {
                t.kind == twobp::util::gantt::SpanKind::BwdP1
            }) {
                assert!(
                    l.end <= p.start + 1e-9 || p.end <= l.start + 1e-9,
                    "rank {}: loss span overlaps a p1 span",
                    w.rank
                );
            }
        }
    }
    let costs = report.measured_costs().expect("complete reports");
    assert!(costs.loss > 0.0);
    let _ = std::fs::remove_dir_all(&dir);
}

/// Measured profiles carry a communication cost floor (ISSUE 6
/// satellite): calibration times every p2p send (serialize + channel
/// write) and `measured_costs()` averages the per-send means over the
/// ranks that actually sent — so `CostModel::comm` is no longer 0.0
/// and plans differing only in hop count stop scoring identically
/// under a measured profile.
#[test]
fn calibration_measures_a_comm_floor() {
    let (dir, _) = setup("comm-floor");
    let base = RunConfig {
        preset: "synthetic".into(),
        artifacts: dir.clone(),
        steps: 2,
        ..RunConfig::default()
    };
    let cluster = Cluster::new(&base).expect("cluster");
    let (costs, calib) = cluster.calibrate(&base).expect("calibrate");
    assert!(
        costs.comm > 0.0,
        "measured CostModel.comm stayed 0.0 — p2p sends not timed"
    );
    // every rank sends in a fused pipeline run (fwd downstream from all
    // but the last, gradients upstream from all but the first)
    for w in &calib.reports {
        assert!(w.mean_comm > 0.0, "rank {} recorded no sends", w.rank);
    }
    // the floor is a mean over sending ranks, so it's bounded by them
    let lo = calib.reports.iter().map(|w| w.mean_comm)
        .fold(f64::INFINITY, f64::min);
    let hi = calib.reports.iter().map(|w| w.mean_comm).fold(0.0, f64::max);
    assert!(costs.comm >= lo - 1e-12 && costs.comm <= hi + 1e-12);
    let _ = std::fs::remove_dir_all(&dir);
}

/// The tentpole acceptance, end to end: on the self-drifting synthetic
/// preset the replan loop must detect the mid-run p2 slowdown, retune
/// exactly once, and the replanned schedule must not lose to the stale
/// one under the drifted costs (strictly beat it when the tunes picked
/// different plans).
#[test]
fn drift_replan_loop_retunes_exactly_once() {
    let out = twobp::experiments::tune_replan(
        8,
        twobp::pipeline::DriftConfig::default(),
        &mut twobp::metrics::observer::NullObserver,
    )
    .expect("replan loop");
    assert!(
        out.contains("replan events: 1"),
        "expected exactly one replan event in:\n{out}"
    );
    let plan_of = |prefix: &str| -> String {
        out.lines()
            .find(|l| l.trim_start().starts_with(prefix))
            .and_then(|l| l.rsplit('[').next())
            .map(|s| s.trim_end().trim_end_matches(']').to_string())
            .unwrap_or_else(|| panic!("missing '{prefix}' line in:\n{out}"))
    };
    let stale = plan_of("stale plan under drifted costs");
    let replanned = plan_of("replanned plan, same costs");
    let speedup: f64 = out
        .lines()
        .find(|l| l.starts_with("post-replan speedup vs stale:"))
        .and_then(|l| l.rsplit(' ').next())
        .map(|s| s.trim_end_matches('x'))
        .unwrap_or_else(|| panic!("missing speedup line in:\n{out}"))
        .parse()
        .expect("speedup parses");
    if stale != replanned {
        assert!(
            speedup > 1.0,
            "retuned plan [{replanned}] did not beat the stale \
             [{stale}] under drifted costs:\n{out}"
        );
    } else {
        // both tunes picked the same plan: the comparison is pure
        // measurement noise around 1.0
        assert!(
            (0.75..=1.35).contains(&speedup),
            "same plan but speedup {speedup}:\n{out}"
        );
    }
}

/// Property test (stub-executed runs): across fuzzed (schedule, ±2BP,
/// microbatch count, steps, seed) cells against one persistent cluster,
/// the stash accountant never goes negative (it panics on underflow —
/// surviving the run is the property), every dynamic class drains at
/// step boundaries (the executor asserts), and its model peak matches
/// a byte-exact replay of the executed op order through
/// `Manifest::mem_model`'s byte classes — plus the sim-timeline order
/// checks in `verify_report_against_sim`.
#[test]
fn prop_accountant_never_negative_and_peak_matches_on_stub_runs() {
    use twobp::util::proptest::{check, gen};

    let (dir, manifest) = setup("prop");
    let base = RunConfig {
        preset: "synthetic".into(),
        artifacts: dir.clone(),
        ..RunConfig::default()
    };
    let cluster = Cluster::new(&base).expect("cluster");
    check(
        "stub-run accounting matches a MemModel replay",
        24,
        |rng| {
            let kind = *gen::pick(
                rng,
                &[ScheduleKind::Naive, ScheduleKind::GPipe,
                  ScheduleKind::OneF1B1, ScheduleKind::OneF1B2,
                  ScheduleKind::OneF1B2EagerP2],
            );
            let two_bp = if kind == ScheduleKind::OneF1B2EagerP2 {
                true
            } else {
                gen::bool(rng)
            };
            let m = gen::usize_in(rng, 1, 6);
            let steps = gen::usize_in(rng, 1, 2);
            let seed = rng.next_u64() % 1000;
            (kind, two_bp, m, steps, seed)
        },
        |&(kind, two_bp, m, steps, seed)| {
            let c = RunConfig {
                schedule: kind,
                two_bp,
                n_microbatches: m,
                steps,
                seed,
                ..base.clone()
            };
            let report = cluster.run(&c).map_err(|e| format!("{e:#}"))?;
            verify_report_against_sim(&report, &manifest, steps)
                .map_err(|e| format!("{e:#}"))?;
            for (r, peak) in report.peak_model_bytes().iter().enumerate() {
                let st = &manifest.stages[r];
                let static_b = st.bytes.params * 3 + st.bytes.grads;
                if *peak < static_b {
                    return Err(format!(
                        "rank {r}: model peak {peak} below static {static_b}"
                    ));
                }
            }
            Ok(())
        },
    );
    let _ = std::fs::remove_dir_all(&dir);
}

/// ISSUE 8 acceptance: an injected rank failure is detected within the
/// comm deadline and surfaces as a **typed** error naming the failing
/// rank and step — bounded time, no hang — and the cluster stays
/// poisoned afterwards (recovery means rebuild + resume, never reuse).
#[test]
fn injected_rank_failure_surfaces_structured_error_in_bounded_time() {
    use twobp::models::synthetic::StubFaultSpec;
    use twobp::pipeline::RunError;

    let dir = std::env::temp_dir()
        .join(format!("twobp-stub-test-fault-fail-{}", std::process::id()));
    let m = 4usize;
    // 0-based stub call counters: call `m` is step 1's first forward
    let spec = SyntheticSpec::tiny_faulty(StubFaultSpec {
        rank: 1,
        kind: "fail".into(),
        at_call: m as u64,
    });
    write_artifacts(&dir, &spec).expect("write faulty artifacts");
    let c = RunConfig {
        preset: spec.preset.clone(),
        artifacts: dir.clone(),
        schedule: ScheduleKind::OneF1B1,
        steps: 3,
        n_microbatches: m,
        comm_timeout_ms: 2_000,
        ..RunConfig::default()
    };
    let cluster = Cluster::new(&c).expect("cluster");
    let t0 = std::time::Instant::now();
    let err = cluster.run(&c).expect_err("injected failure must surface");
    let elapsed = t0.elapsed();
    assert!(
        elapsed < std::time::Duration::from_secs(10),
        "detection took {elapsed:?} — not fail-fast"
    );
    match err.downcast_ref::<RunError>() {
        Some(RunError::RankFailed { rank, step, cause }) => {
            assert_eq!(*rank, 1, "{err:#}");
            assert_eq!(*step, 1, "{err:#}");
            assert!(cause.contains("injected failure"), "{cause}");
        }
        other => panic!("expected typed RankFailed, got {other:?}: {err:#}"),
    }
    // poisoned: later runs refuse fast with the same typed failure
    let again = cluster.run(&c).expect_err("poisoned cluster must refuse");
    assert!(again.downcast_ref::<RunError>().is_some(), "{again:#}");
    let _ = std::fs::remove_dir_all(&dir);
}

/// A stalled (not dead) rank trips the receive **deadline** on a
/// neighbor: the typed error is `CommTimeout`, and it fires at roughly
/// the configured deadline — far sooner than the stall itself lasts,
/// proving detection comes from the timeout, not the stall ending.
#[test]
fn stalled_rank_times_out_as_comm_timeout() {
    use twobp::models::synthetic::StubFaultSpec;
    use twobp::pipeline::RunError;

    let dir = std::env::temp_dir()
        .join(format!("twobp-stub-test-fault-stall-{}", std::process::id()));
    let m = 4usize;
    let spec = SyntheticSpec::tiny_faulty(StubFaultSpec {
        rank: 1,
        kind: format!("stall-{}", 3_000_000_000u64), // 3 s
        at_call: m as u64,
    });
    write_artifacts(&dir, &spec).expect("write faulty artifacts");
    let c = RunConfig {
        preset: spec.preset.clone(),
        artifacts: dir.clone(),
        schedule: ScheduleKind::OneF1B1,
        steps: 3,
        n_microbatches: m,
        comm_timeout_ms: 150,
        ..RunConfig::default()
    };
    let cluster = Cluster::new(&c).expect("cluster");
    let t0 = std::time::Instant::now();
    let err = cluster.run(&c).expect_err("stall must trip the deadline");
    let elapsed = t0.elapsed();
    assert!(
        elapsed < std::time::Duration::from_millis(2_500),
        "took {elapsed:?} — the 150ms deadline did not fire \
         (the 3s stall would have ended first)"
    );
    match err.downcast_ref::<RunError>() {
        // which neighbor of the stalled rank hits its deadline first is
        // a race, so the waiting rank/step are not asserted
        Some(RunError::CommTimeout { cause, .. }) => {
            assert!(!cause.is_empty());
        }
        other => panic!("expected typed CommTimeout, got {other:?}: {err:#}"),
    }
    let _ = std::fs::remove_dir_all(&dir);
}

/// ISSUE 8 acceptance: checkpoint/resume is bit-identical.  For two
/// schedules ± 2BP: N straight steps == N/2 steps + on-disk checkpoint
/// + a fresh cluster resuming for N/2 — byte for byte on every rank's
/// parameters (`param_digests`), and the per-step losses line up
/// exactly across the splice point.
#[test]
fn checkpoint_resume_is_bit_identical_across_schedules_and_2bp() {
    let (dir, _) = setup("ckpt-resume");
    let (total, half) = (4usize, 2usize);
    let m = 4;
    for kind in [ScheduleKind::GPipe, ScheduleKind::OneF1B1] {
        for two_bp in [false, true] {
            let tag = format!("{}-2bp={two_bp}", kind.name());
            let ckpt = std::env::temp_dir().join(format!(
                "twobp-stub-test-ckpt-{tag}-{}",
                std::process::id()
            ));
            let _ = std::fs::remove_dir_all(&ckpt);
            let straight =
                train(&cfg(&dir, kind, two_bp, total, m)).expect("straight");
            let mut first = cfg(&dir, kind, two_bp, half, m);
            first.checkpoint_every = half;
            first.checkpoint_dir = Some(ckpt.clone());
            let a = train(&first).expect("first half");
            let mut second = cfg(&dir, kind, two_bp, total - half, m);
            second.resume = Some(ckpt.clone());
            let b = train(&second).expect("resumed half");
            assert_eq!(
                b.param_digests(),
                straight.param_digests(),
                "{tag}: resumed parameters diverge from the straight run"
            );
            assert_eq!(a.losses[..], straight.losses[..half], "{tag}");
            assert_eq!(b.losses[..], straight.losses[half..], "{tag}");
            let _ = std::fs::remove_dir_all(&ckpt);
        }
    }
    let _ = std::fs::remove_dir_all(&dir);
}
