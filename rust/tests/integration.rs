//! Integration tests over the real runtime + artifacts.
//!
//! These need the `pjrt` feature (vendored xla crate) and `make
//! artifacts` to have produced the `*-tiny` presets; every test skips
//! (with a loud message) when artifacts are missing so `cargo test`
//! stays green on a fresh checkout.
#![cfg(feature = "pjrt")]

use std::path::Path;

use twobp::config::{P2Mode, RunConfig};
use twobp::pipeline::train;
use twobp::schedule::ScheduleKind;

fn have(preset: &str) -> bool {
    let ok = Path::new("artifacts").join(preset).join("manifest.json").exists();
    if !ok {
        eprintln!("SKIP: artifacts/{preset} missing (run `make artifacts`)");
    }
    ok
}

fn run(preset: &str, kind: ScheduleKind, two_bp: bool, steps: usize,
       p2_mode: P2Mode) -> twobp::pipeline::RunReport {
    run_m(preset, kind, two_bp, steps, p2_mode, 0)
}

fn run_m(preset: &str, kind: ScheduleKind, two_bp: bool, steps: usize,
         p2_mode: P2Mode, m: usize) -> twobp::pipeline::RunReport {
    let cfg = RunConfig {
        preset: preset.into(),
        schedule: kind,
        two_bp,
        steps,
        p2_mode,
        n_microbatches: m,
        data_cycle: 2,
        ..RunConfig::default()
    };
    train(&cfg).expect("training run failed")
}

#[test]
fn transformer_tiny_loss_decreases() {
    if !have("transformer-tiny") {
        return;
    }
    let report = run("transformer-tiny", ScheduleKind::OneF1B1, true, 10,
                     P2Mode::Loop);
    let first = report.losses[0];
    let last = *report.losses.last().unwrap();
    assert!(
        last < first - 0.1,
        "loss should fall: {first} -> {last}"
    );
}

/// The paper's implicit core claim: 2BP is *semantics-preserving* — the
/// same data + seed must yield identical parameters whether backward is
/// fused or split/reordered, for every schedule.
#[test]
fn two_bp_preserves_training_semantics_across_schedules() {
    if !have("transformer-tiny") {
        return;
    }
    // fixed M = 4 for every schedule: equivalence requires identical
    // data and effective batch size (1F1B-2's default M = 2N differs)
    let baseline = run_m("transformer-tiny", ScheduleKind::GPipe, false, 2,
                         P2Mode::Loop, 4);
    let base_ck = baseline.param_checksum();
    let base_loss = baseline.losses.clone();
    for kind in [ScheduleKind::Naive, ScheduleKind::GPipe,
                 ScheduleKind::OneF1B1, ScheduleKind::OneF1B2] {
        for two_bp in [false, true] {
            let r = run_m("transformer-tiny", kind, two_bp, 2,
                          P2Mode::Loop, 4);
            assert_eq!(
                r.losses.len(), base_loss.len(),
                "{} 2bp={two_bp}", kind.name()
            );
            for (a, b) in r.losses.iter().zip(base_loss.iter()) {
                assert!(
                    (a - b).abs() < 1e-4,
                    "{} 2bp={two_bp}: loss {a} vs baseline {b}",
                    kind.name()
                );
            }
            let ck = r.param_checksum();
            let rel = (ck - base_ck).abs() / base_ck.abs().max(1e-12);
            assert!(
                rel < 1e-5,
                "{} 2bp={two_bp}: param checksum {ck} vs {base_ck} (rel {rel})",
                kind.name()
            );
        }
    }
}

/// Concat-p2 (Fig 2) must produce the same gradients as the loop form.
#[test]
fn concat_p2_equals_loop_p2() {
    if !have("transformer-tiny") {
        return;
    }
    let a = run("transformer-tiny", ScheduleKind::GPipe, true, 2, P2Mode::Loop);
    let b = run("transformer-tiny", ScheduleKind::GPipe, true, 2,
                P2Mode::Concat);
    let (ca, cb) = (a.param_checksum(), b.param_checksum());
    let rel = (ca - cb).abs() / ca.abs().max(1e-12);
    assert!(rel < 1e-5, "concat {cb} vs loop {ca} (rel {rel})");
}

/// 2BP must not *lower* pipeline throughput.  Both plans are replayed
/// against the *same* measured cost model (calibrated from a naive run,
/// whose ops never overlap across rank threads) — measuring inside each
/// schedule separately double-counts single-core contention and is
/// exactly the bias DESIGN.md §3's calibration methodology removes.
#[test]
fn two_bp_throughput_gain_nonnegative() {
    if !have("transformer-tiny") {
        return;
    }
    let calib = run("transformer-tiny", ScheduleKind::Naive, false, 3,
                    P2Mode::Loop);
    let costs = calib.measured_costs().expect("complete rank reports");
    let sim_tput = |two_bp: bool| -> f64 {
        let plan = twobp::schedule::generate(
            ScheduleKind::OneF1B1, two_bp, costs.fwd.len(), 0, false);
        let res = twobp::sim::simulate(&plan, &costs, None).unwrap();
        res.throughput(calib.samples_per_step / plan.n_microbatches,
                       plan.n_microbatches)
    };
    let (t0, t1) = (sim_tput(false), sim_tput(true));
    assert!(
        t1 > t0 * 0.999,
        "2BP throughput {t1} should be >= baseline {t0}"
    );
}

/// Fig 4 direction: 2BP increases peak memory (res2+inter held longer).
#[test]
fn two_bp_increases_peak_memory_on_real_runs() {
    if !have("transformer-tiny") {
        return;
    }
    let base = run("transformer-tiny", ScheduleKind::OneF1B2, false, 2,
                   P2Mode::Loop);
    let with = run("transformer-tiny", ScheduleKind::OneF1B2, true, 2,
                   P2Mode::Loop);
    assert!(
        with.max_peak() >= base.max_peak(),
        "2BP peak {} < baseline {}",
        with.max_peak(),
        base.max_peak()
    );
}

/// All four tiny presets train without stash leaks under the
/// memory-heaviest schedule (the accountant panics on leaks).
#[test]
fn all_archs_run_one_step_clean() {
    for preset in ["transformer-tiny", "bert-tiny", "mamba-tiny",
                   "resnet-tiny"] {
        if !have(preset) {
            continue;
        }
        let r = run(preset, ScheduleKind::OneF1B2, true, 1, P2Mode::Loop);
        assert_eq!(r.losses.len(), 1, "{preset}");
        assert!(r.losses[0].is_finite(), "{preset} loss finite");
        assert!(r.max_peak() > 0, "{preset} memory accounted");
    }
}

/// Deterministic reruns: same seed => identical losses.
#[test]
fn reruns_are_deterministic() {
    if !have("bert-tiny") {
        return;
    }
    let a = run("bert-tiny", ScheduleKind::OneF1B1, true, 2, P2Mode::Loop);
    let b = run("bert-tiny", ScheduleKind::OneF1B1, true, 2, P2Mode::Loop);
    assert_eq!(a.losses, b.losses);
    assert_eq!(a.param_checksum(), b.param_checksum());
}

/// The eager-p2 variant (Fig 5) runs and cuts (or matches) the plain
/// 1F1B-2+2BP peak.
#[test]
fn eager_p2_variant_runs_and_bounds_memory() {
    if !have("transformer-tiny") {
        return;
    }
    let plain = run("transformer-tiny", ScheduleKind::OneF1B2, true, 2,
                    P2Mode::Loop);
    let eager = run("transformer-tiny", ScheduleKind::OneF1B2EagerP2, true, 2,
                    P2Mode::Loop);
    assert!(eager.max_peak() <= plain.max_peak());
    // still trains the same function
    for (a, b) in eager.losses.iter().zip(plain.losses.iter()) {
        assert!((a - b).abs() < 1e-4);
    }
}

/// Measured per-op costs are sane: every op kind took nonzero time and
/// p1 ≳ fwd (backward does strictly more work).
#[test]
fn measured_costs_sane() {
    if !have("transformer-tiny") {
        return;
    }
    let r = run("transformer-tiny", ScheduleKind::GPipe, true, 3,
                P2Mode::Loop);
    let c = r.measured_costs().expect("complete rank reports");
    for rank in 0..c.fwd.len() {
        assert!(c.fwd[rank] > 0.0);
        assert!(c.p1[rank] > 0.0);
        assert!(c.p2[rank] > 0.0);
        assert!(c.opt[rank] > 0.0);
    }
    // the loss span is timed separately on the last rank (never folded
    // into its p1 mean)
    assert!(c.loss > 0.0);
}
