#!/usr/bin/env python3
"""CI validator for the observability artifacts (docs/OBSERVABILITY.md).

Modes:

  check_obs.py trace FILE [--expect-executed]
      FILE is a valid Chrome Trace Event document: a JSON object with
      "displayTimeUnit" and a "traceEvents" list whose "X" events carry
      name/cat/ph/ts/dur/pid/tid and whose processes are named by "M"
      metadata.  --expect-executed additionally requires BOTH timeline
      groups (predicted pids start at 1, executed at 1001).

  check_obs.py metrics FILE [--require PREFIX ...]
      FILE is a JSONL run log: every line a JSON object with "kind" and
      "name", counter/gauge values non-negative, event "seq" dense from
      0.  Each --require PREFIX must match at least one line's name.

  check_obs.py diff-metrics A B
      The two run logs must be identical after stripping every nested
      "wall" object (the only place wall-clock-derived values may live).

  check_obs.py fault FILE
      FILE is a `twobp bench faults --metrics-out` run log: every
      "fault.cell" event carries the injected rank/step, an "injected"
      kind (fail|stall) consistent with how it was detected
      (rank_failed|comm_timeout), a salvaged-step count, recovered=true,
      and wall-only latencies; the fault.* counters must agree with the
      cell count.  The detecting rank must NOT appear (it is racy for
      stalls); two same-seed logs stay diff-metrics-clean.

  check_obs.py diff-trace A B
      The two traces must be identical after dropping "ts"/"dur" from
      events (executed timelines carry measured timings; everything
      else — event order, names, pids, tids, metadata — must agree).

Exit 0 on success; prints the first violation and exits 1 otherwise.
"""

import json
import sys

EXECUTED_PID_BASE = 1001


def fail(msg):
    print(f"check_obs: FAIL: {msg}", file=sys.stderr)
    sys.exit(1)


def strip_wall(node):
    """Recursively remove every "wall" key (the quarantine contract)."""
    if isinstance(node, dict):
        return {
            k: strip_wall(v) for k, v in node.items() if k != "wall"
        }
    if isinstance(node, list):
        return [strip_wall(v) for v in node]
    return node


def load_trace(path):
    with open(path, encoding="utf-8") as f:
        doc = json.load(f)
    if not isinstance(doc, dict):
        fail(f"{path}: top level is not a JSON object")
    if doc.get("displayTimeUnit") != "ms":
        fail(f"{path}: missing displayTimeUnit")
    events = doc.get("traceEvents")
    if not isinstance(events, list) or not events:
        fail(f"{path}: traceEvents missing or empty")
    return doc, events


def check_trace(path, expect_executed):
    _, events = load_trace(path)
    xs = [e for e in events if e.get("ph") == "X"]
    if not xs:
        fail(f"{path}: no complete ('X') span events")
    for i, e in enumerate(xs):
        for key in ("name", "cat", "ph", "ts", "dur", "pid", "tid"):
            if key not in e:
                fail(f"{path}: X event {i} missing '{key}': {e}")
        if e["dur"] < 0:
            fail(f"{path}: X event {i} has negative dur: {e}")
    named = {
        e.get("pid")
        for e in events
        if e.get("ph") == "M" and e.get("name") == "process_name"
    }
    span_pids = {e["pid"] for e in xs}
    if not span_pids <= named:
        fail(f"{path}: spans on unnamed pids {sorted(span_pids - named)}")
    predicted = [p for p in span_pids if p < EXECUTED_PID_BASE]
    executed = [p for p in span_pids if p >= EXECUTED_PID_BASE]
    if not predicted:
        fail(f"{path}: no predicted-timeline spans (pid < 1001)")
    if expect_executed and not executed:
        fail(f"{path}: --expect-executed but no executed spans (pid >= 1001)")
    print(
        f"check_obs: {path} OK — {len(xs)} spans, "
        f"{len(predicted)} predicted / {len(executed)} executed ranks"
    )


def load_metrics(path):
    lines = []
    with open(path, encoding="utf-8") as f:
        for i, raw in enumerate(f):
            raw = raw.strip()
            if not raw:
                fail(f"{path}:{i + 1}: blank line in JSONL")
            try:
                lines.append(json.loads(raw))
            except json.JSONDecodeError as e:
                fail(f"{path}:{i + 1}: bad JSON ({e})")
    if not lines:
        fail(f"{path}: empty run log")
    return lines


def check_metrics(path, require):
    lines = load_metrics(path)
    kinds = {"event", "counter", "gauge", "histogram"}
    seq = 0
    for i, line in enumerate(lines):
        if line.get("kind") not in kinds:
            fail(f"{path}:{i + 1}: bad kind {line.get('kind')!r}")
        if not isinstance(line.get("name"), str) or not line["name"]:
            fail(f"{path}:{i + 1}: missing name")
        if line["kind"] == "event":
            if line.get("seq") != seq:
                fail(f"{path}:{i + 1}: seq {line.get('seq')} != {seq}")
            seq += 1
        if line["kind"] == "counter" and line.get("value", 0) < 0:
            fail(f"{path}:{i + 1}: negative counter")
    names = [line["name"] for line in lines]
    for prefix in require:
        if not any(n.startswith(prefix) for n in names):
            fail(f"{path}: no metric named '{prefix}*' (have: {names})")
    print(
        f"check_obs: {path} OK — {seq} events, "
        f"{len(lines) - seq} aggregate lines"
    )


def check_fault(path):
    lines = load_metrics(path)
    cells = [
        line
        for line in lines
        if line.get("kind") == "event" and line.get("name") == "fault.cell"
    ]
    if not cells:
        fail(f"{path}: no fault.cell events")
    pairing = {"fail": "rank_failed", "stall": "comm_timeout"}
    for e in cells:
        where = f"{path}: fault.cell seq {e.get('seq')}"
        for key in ("cell", "rank", "step", "injected", "detected_as",
                    "steps_before", "recovered"):
            if key not in e:
                fail(f"{where}: missing '{key}': {e}")
        for key in ("cell", "rank", "step", "steps_before"):
            if not isinstance(e[key], (int, float)) or e[key] < 0:
                fail(f"{where}: bad {key}={e[key]!r}")
        if e["injected"] not in pairing:
            fail(f"{where}: bad injected kind {e['injected']!r}")
        if e["detected_as"] != pairing[e["injected"]]:
            fail(
                f"{where}: injected {e['injected']!r} detected as "
                f"{e['detected_as']!r} (want {pairing[e['injected']]!r})"
            )
        if e["recovered"] is not True:
            fail(f"{where}: recovered={e['recovered']!r}")
        wall = e.get("wall")
        if not isinstance(wall, dict):
            fail(f"{where}: missing wall object")
        for key in ("detect_s", "recovery_s", "goodput_steps_per_s"):
            v = wall.get(key)
            if not isinstance(v, (int, float)) or v < 0:
                fail(f"{where}: bad wall.{key}={v!r}")
    counters = {
        line["name"]: line.get("value")
        for line in lines
        if line.get("kind") == "counter"
    }
    n = len(cells)
    if counters.get("fault.cells") != n:
        fail(f"{path}: fault.cells={counters.get('fault.cells')} != {n}")
    injected = sum(
        counters.get(f"fault.injected.{k}", 0) for k in ("fail", "stall")
    )
    if injected != n:
        fail(f"{path}: fault.injected.* sums to {injected} != {n}")
    detected = sum(
        counters.get(f"fault.detected.{k}", 0)
        for k in ("rank_failed", "comm_timeout")
    )
    if detected != n:
        fail(f"{path}: fault.detected.* sums to {detected} != {n}")
    if counters.get("fault.recovered") != n:
        fail(
            f"{path}: fault.recovered={counters.get('fault.recovered')} "
            f"!= {n}"
        )
    print(f"check_obs: {path} OK — {n} fault cells, all recovered")


def diff_metrics(a, b):
    sa = [strip_wall(line) for line in load_metrics(a)]
    sb = [strip_wall(line) for line in load_metrics(b)]
    if len(sa) != len(sb):
        fail(f"line counts differ: {a}={len(sa)} {b}={len(sb)}")
    for i, (la, lb) in enumerate(zip(sa, sb)):
        if la != lb:
            fail(
                f"line {i + 1} differs after stripping wall:\n"
                f"  {a}: {json.dumps(la, sort_keys=True)}\n"
                f"  {b}: {json.dumps(lb, sort_keys=True)}"
            )
    print(f"check_obs: {a} == {b} modulo wall ({len(sa)} lines)")


def diff_trace(a, b):
    def normalize(path):
        _, events = load_trace(path)
        return [
            {k: v for k, v in e.items() if k not in ("ts", "dur")}
            for e in events
        ]

    na, nb = normalize(a), normalize(b)
    if len(na) != len(nb):
        fail(f"event counts differ: {a}={len(na)} {b}={len(nb)}")
    for i, (ea, eb) in enumerate(zip(na, nb)):
        if ea != eb:
            fail(
                f"event {i} differs after dropping ts/dur:\n"
                f"  {a}: {json.dumps(ea, sort_keys=True)}\n"
                f"  {b}: {json.dumps(eb, sort_keys=True)}"
            )
    print(f"check_obs: {a} == {b} modulo ts/dur ({len(na)} events)")


def main(argv):
    if len(argv) < 3:
        print(__doc__)
        return 2
    mode, args = argv[1], argv[2:]
    if mode == "trace":
        expect = "--expect-executed" in args
        paths = [a for a in args if not a.startswith("--")]
        check_trace(paths[0], expect)
    elif mode == "metrics":
        require = []
        if "--require" in args:
            i = args.index("--require")
            require = args[i + 1:]
            args = args[:i]
        check_metrics(args[0], require)
    elif mode == "fault":
        check_fault(args[0])
    elif mode == "diff-metrics":
        diff_metrics(args[0], args[1])
    elif mode == "diff-trace":
        diff_trace(args[0], args[1])
    else:
        fail(f"unknown mode '{mode}'")
    return 0


if __name__ == "__main__":
    sys.exit(main(sys.argv))
